"""HTTP/1.1 serving surface: keep-alive, range GETs, the remote write
path, zero-copy sendfile and the multi-store router.

Covers the PR's serving acceptance criteria: suffix/out-of-bounds/multi
range semantics (206 / 416 / 200-full fallback), range over a BitX-delta
tensor byte-identical to slicing the full GET, connection reuse across
requests, PUT → spooled ingest job → ranged read-back against a routed
2-root server, and /stats keeping the flat single-root shape while
aggregating per-root under a router.
"""

import asyncio
import json
import http.client
import os
import threading
import time
from collections import OrderedDict

import numpy as np
import pytest

from repro.core.bitx import BitXReader
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st
from repro.serve.router import StoreRouter
from repro.serve.singleflight import TieredResponseCache
from repro.serve.store_server import (RetrievalEngine, ServerThread,
                                      parse_byte_range)


def _write_model(path, rng, n_tensors=3, n=2048, scale=0.02, blob=False):
    tensors = {f"model.t{i}.weight": (rng.randn(n) * scale).astype(np.float32)
               for i in range(n_tensors)}
    if blob:  # incompressible non-float payload -> `stored` codec on disk
        tensors["tok.table"] = np.frombuffer(rng.bytes(32768), np.uint8).copy()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path)
    return tensors


def _write_finetune(path, base_tensors, rng, sigma=1e-3):
    ft = {k: ((v + rng.randn(*v.shape).astype(np.float32) * sigma)
              .astype(np.float32) if v.dtype.kind == "f" else v.copy())
          for k, v in base_tensors.items()}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(ft, path)
    return ft


class Client:
    """Thin keep-alive HTTP client: one connection, many requests."""

    def __init__(self, srv):
        self.conn = http.client.HTTPConnection(srv.host, srv.port, timeout=60)

    def get(self, path, headers=None):
        self.conn.request("GET", path, headers=headers or {})
        r = self.conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()

    def put(self, path, body):
        self.conn.request("PUT", path, body=body)
        r = self.conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()

    def post(self, path, body=b""):
        self.conn.request("POST", path, body=body)
        r = self.conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()

    def close(self):
        self.conn.close()


@pytest.fixture
def family_store(tmp_path):
    """Base + BitX fine-tune + an incompressible (`stored`) tensor."""
    rng = np.random.RandomState(42)
    base_path = str(tmp_path / "hub" / "org" / "base" / "model.safetensors")
    base = _write_model(base_path, rng, blob=True)
    ft_path = str(tmp_path / "hub" / "u0" / "ft" / "model.safetensors")
    _write_finetune(ft_path, base, rng)
    store = ZLLMStore(str(tmp_path / "store"), workers=2)
    store.ingest_file(base_path, "org/base")
    store.ingest_file(ft_path, "u0/ft", declared_base="org/base")
    yield store, {"org/base": open(base_path, "rb").read(),
                  "u0/ft": open(ft_path, "rb").read()}
    store.close()


# ---------------------------------------------------------------------------
# Range parser unit coverage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("header,size,expect", [
    (None, 100, None),
    ("bytes=0-9", 100, (0, 9)),
    ("bytes=10-", 100, (10, 99)),
    ("bytes=-10", 100, (90, 99)),
    ("bytes=-200", 100, (0, 99)),        # oversized suffix clamps to all
    ("bytes=0-500", 100, (0, 99)),       # end clamps to size-1
    ("bytes=100-", 100, "unsat"),        # first-pos at EOF
    ("bytes=-0", 100, "unsat"),          # empty suffix
    ("bytes=-5", 0, "unsat"),            # empty body
    ("bytes=0-1,4-5", 100, None),        # multi-range -> full fallback
    ("bytes=5-2", 100, None),            # inverted -> full fallback
    ("bytes=abc", 100, None),
    ("chars=0-5", 100, None),
    # RFC 9110 hardening (regression: int() is laxer than the ABNF's
    # 1*DIGIT — it accepts "+5", "1_0", inner whitespace and unicode
    # digits, so these grammar-invalid forms used to answer 206)
    ("bytes=-1_0", 100, None),           # int("1_0") == 10 — not a DIGIT run
    ("bytes=-+5", 100, None),            # int("+5") == 5 — sign not allowed
    ("bytes=- 5", 100, None),            # int(" 5") == 5 — inner whitespace
    ("bytes=-٥", 100, None),        # int("٥") == 5 — unicode digit
    ("bytes=٠-٥", 100, None),  # \d matches unicode without re.ASCII
    ("bytes=5 -9", 100, None),           # whitespace inside the spec
    ("bytes=0- 5", 100, None),
    ("bytes=-00", 100, "unsat"),         # zero-length suffix, padded form
    ("bytes=00-05", 100, (0, 5)),        # leading zeros ARE valid 1*DIGIT
    ("bytes=" + "9" * 30 + "-", 100, "unsat"),   # huge first-pos: past EOF
    ("bytes=0-" + "9" * 30, 100, (0, 99)),       # huge last-pos clamps
    ("bytes=-" + "9" * 30, 100, (0, 99)),        # huge suffix clamps to all
])
def test_parse_byte_range(header, size, expect):
    assert parse_byte_range(header, size) == expect


# ---------------------------------------------------------------------------
# Range GETs over HTTP
# ---------------------------------------------------------------------------

def test_file_range_semantics(family_store):
    store, originals = family_store
    data = originals["org/base"]
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            path = "/repo/org/base/file/model.safetensors"
            status, headers, body = c.get(path)
            assert status == 200 and body == data
            assert headers["accept-ranges"] == "bytes"

            status, headers, body = c.get(path, {"Range": "bytes=100-299"})
            assert status == 206 and body == data[100:300]
            assert headers["content-range"] == f"bytes 100-299/{len(data)}"

            status, _, body = c.get(path, {"Range": "bytes=-64"})
            assert status == 206 and body == data[-64:]

            status, headers, body = c.get(
                path, {"Range": f"bytes={len(data)}-{len(data) + 10}"})
            assert status == 416
            assert headers["content-range"] == f"bytes */{len(data)}"

            # multi-range: deliberate 200-full fallback
            status, _, body = c.get(path, {"Range": "bytes=0-1,10-11"})
            assert status == 200 and body == data
        finally:
            c.close()


def test_bitx_tensor_range_matches_full_get_slice(family_store):
    """Satellite acceptance: a range over a BitX-delta tensor must be
    byte-identical to slicing the full GET (and the direct store read)."""
    store, _ = family_store
    # pick a tensor the fine-tune actually stored as a BitX delta
    rec = store.file_index["u0/ft/model.safetensors"]
    reader = BitXReader.open(rec["path"])
    bitx_names = [r.name for r in reader.records if r.codec == "bitx"]
    reader.close()
    assert bitx_names, "fixture must produce at least one BitX record"
    name = bitx_names[0]
    direct, meta = store.retrieve_tensor("u0/ft", "model.safetensors", name)

    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            path = f"/repo/u0/ft/tensor/{name}"
            status, headers, full = c.get(path)
            assert status == 200 and full == direct
            assert headers["x-tensor-codec"] == "bitx"
            n = len(full)
            for rng_hdr, lo, hi in [("bytes=0-99", 0, 100),
                                    (f"bytes={n // 2}-", n // 2, n),
                                    ("bytes=-128", n - 128, n),
                                    (f"bytes=7-{n + 999}", 7, n)]:
                status, _, part = c.get(path, {"Range": rng_hdr})
                assert status == 206
                assert part == full[lo:hi] == direct[lo:hi]
            # the decode ran once per read generation: every slice above
            # was cut from the cached buffer, not re-decoded
            sf = srv.server.engine.stats()["singleflight"]
            assert sf["leaders"] <= 2  # one file decode path + one tensor
        finally:
            c.close()


def test_stored_tensor_served_via_sendfile(family_store):
    store, _ = family_store
    direct, meta = store.retrieve_tensor("org/base", "model.safetensors",
                                         "tok.table")
    assert meta["codec"] == "stored"
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            path = "/repo/org/base/tensor/tok.table"
            status, headers, full = c.get(path)
            assert status == 200 and full == direct
            assert headers["x-zllm-sendfile"] == "1"
            assert headers["x-tensor-codec"] == "stored"
            status, headers, part = c.get(path, {"Range": "bytes=1000-1999"})
            assert status == 206 and part == direct[1000:2000]
            assert headers["x-zllm-sendfile"] == "1"
            status, headers, _ = c.get(path,
                                       {"Range": f"bytes={len(direct)}-"})
            assert status == 416
            assert srv.server.http["sendfile_responses"] >= 2
        finally:
            c.close()


def test_keepalive_connection_reuse(family_store):
    store, originals = family_store
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            for _ in range(16):
                status, headers, _ = c.get("/healthz")
                assert status == 200
                assert headers["connection"] == "keep-alive"
            status, _, body = c.get("/repo/org/base/file/model.safetensors")
            assert status == 200 and body == originals["org/base"]
        finally:
            c.close()
        # 17+ requests, exactly one connection
        assert srv.server.http["requests"] >= 17
        assert srv.server.http["connections"] == 1


# ---------------------------------------------------------------------------
# Remote write path
# ---------------------------------------------------------------------------

def test_put_sync_then_read_back(family_store, tmp_path):
    store, _ = family_store
    rng = np.random.RandomState(7)
    p = str(tmp_path / "new" / "model.safetensors")
    _write_model(p, rng, scale=1.0)
    data = open(p, "rb").read()
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/org/new/file/model.safetensors?sync=1", data)
            out = json.loads(body)
            assert status == 200 and out["job"]["state"] == "done", out
            res = out["job"]["results"][0]
            assert res["repo_id"] == "org/new" and res["raw_bytes"] == len(data)
            status, _, got = c.get("/repo/org/new/file/model.safetensors")
            assert status == 200 and got == data
            # the spool was cleaned up after the job finished
            assert os.listdir(store.spool_dir()) == []
        finally:
            c.close()


def test_put_async_job_lifecycle_and_declared_base(family_store, tmp_path):
    """Async PUT: 202 + job id, /admin/jobs reaches `done`, the declared
    base (?base=) produces BitX records, and the result is bit-exact."""
    store, originals = family_store
    rng = np.random.RandomState(11)
    base_tensors = st.load_file(
        str(tmp_path / "hub" / "org" / "base" / "model.safetensors"))
    p = str(tmp_path / "ft2" / "model.safetensors")
    _write_finetune(p, base_tensors, rng)
    data = open(p, "rb").read()
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/u1/ft2/file/model.safetensors?base=org/base", data)
            out = json.loads(body)
            assert status == 202 and "job_id" in out, out
            deadline = time.time() + 60
            while True:
                status, _, body = c.get(f"/admin/jobs?job={out['job_id']}")
                job = json.loads(body)
                if job["state"] in ("done", "failed"):
                    break
                assert time.time() < deadline, job
                time.sleep(0.02)
            assert job["state"] == "done", job
            assert job["results"][0]["base_id"] == "org/base"
            assert job["results"][0]["n_bitx"] >= 1
            status, _, got = c.get("/repo/u1/ft2/file/model.safetensors")
            assert status == 200 and got == data
            # job listing includes the finished job
            status, _, body = c.get("/admin/jobs")
            assert any(j["job_id"] == out["job_id"]
                       for j in json.loads(body)["jobs"])
        finally:
            c.close()
    assert store.fsck(spot_check=2).ok


def test_put_base_survives_restart_and_serves_finetunes(tmp_path):
    """Regression: the job worker must adopt a spooled BASE into
    basecache/ BEFORE persisting the index — a restarted store must not
    resurrect a dead spool path in base_paths/families (which would make
    every later same-family ingest fail at the bit-distance matcher)."""
    rng = np.random.RandomState(21)
    base_path = str(tmp_path / "hub" / "model.safetensors")
    base = _write_model(base_path, rng)
    root = str(tmp_path / "store")
    store = ZLLMStore(root, workers=2)
    with ServerThread(store, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/org/base/file/model.safetensors?sync=1",
                open(base_path, "rb").read())
            assert status == 200, body
        finally:
            c.close()
    store.close()

    # fresh process: every persisted base path must exist on disk, and a
    # declared-base fine-tune must still delta against the adopted base
    store2 = ZLLMStore(root, workers=2)
    assert store2.load_index()
    for bid, p in store2.base_paths.items():
        assert os.path.exists(p), f"base path for {bid} rotted: {p}"
    ft_path = str(tmp_path / "ft" / "model.safetensors")
    _write_finetune(ft_path, base, rng)
    res = store2.ingest_file(ft_path, "u9/ft", declared_base="org/base")
    assert res.base_id == "org/base" and res.n_bitx >= 1
    assert store2.retrieve_file("u9/ft", "model.safetensors") == \
        open(ft_path, "rb").read()
    store2.close()


def test_corrupt_stored_span_is_never_served(family_store):
    """verify=True must cover the sendfile path too: flip a byte inside a
    stored-codec span on disk — the span check fails, the decode path
    takes over, and ITS verification turns the rot into a 500 (never a
    silent 200 of corrupt bytes)."""
    store, _ = family_store
    cpath, off, ln, meta = store.tensor_sendfile_span(
        "org/base", "model.safetensors", "tok.table")
    with open(cpath, "r+b") as f:
        f.seek(off + 7)
        orig = f.read(1)
        f.seek(off + 7)
        f.write(bytes([orig[0] ^ 0xFF]))
    with ServerThread(store, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, headers, body = c.get("/repo/org/base/tensor/tok.table")
            assert status == 500, (status, headers)
            assert "x-zllm-sendfile" not in headers
            assert srv.server.http["sendfile_responses"] == 0
        finally:
            c.close()


def test_put_without_content_length_is_rejected(family_store):
    store, _ = family_store
    with ServerThread(store, max_concurrency=2) as srv:
        import socket
        s = socket.create_connection((srv.host, srv.port), timeout=30)
        try:
            s.sendall(b"PUT /repo/a/b/file/f HTTP/1.1\r\n"
                      b"transfer-encoding: chunked\r\n\r\n")
            resp = s.recv(4096)
            assert b"411" in resp.split(b"\r\n", 1)[0]
        finally:
            s.close()


# ---------------------------------------------------------------------------
# Multi-store router
# ---------------------------------------------------------------------------

@pytest.fixture
def two_root_router(tmp_path):
    s0 = ZLLMStore(str(tmp_path / "r0"), workers=2)
    s1 = ZLLMStore(str(tmp_path / "r1"), workers=2)
    router = StoreRouter(OrderedDict([("r0", s0), ("r1", s1)]))
    yield router
    router.close()


def test_router_placement_is_deterministic_and_spreads(two_root_router):
    router = two_root_router
    placed = {router.place(f"org/model-{i}") for i in range(64)}
    assert placed == {"r0", "r1"}          # both roots get keys
    for i in range(16):
        rid = f"org/model-{i}"
        assert router.place(rid) == router.place(rid)


def test_router_put_get_and_aggregated_stats(two_root_router, tmp_path):
    router = two_root_router
    rng = np.random.RandomState(3)
    payloads = {}
    for i in range(4):
        p = str(tmp_path / f"m{i}" / "model.safetensors")
        _write_model(p, rng, scale=1.0)
        payloads[f"org/m{i}"] = open(p, "rb").read()

    with ServerThread(router, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            for rid, data in payloads.items():
                status, _, body = c.put(f"/repo/{rid}/file/model.safetensors"
                                        f"?sync=1", data)
                assert status == 200, body
            # reads route to whichever root holds the repo
            for rid, data in payloads.items():
                status, _, got = c.get(f"/repo/{rid}/file/model.safetensors")
                assert status == 200 and got == data
                # ranged read through the router too
                status, _, part = c.get(f"/repo/{rid}/file/model.safetensors",
                                        {"Range": "bytes=32-95"})
                assert status == 206 and part == data[32:96]
            status, _, body = c.get("/stats")
            stats = json.loads(body)
            # aggregated multi-root shape
            assert stats["store"]["n_roots"] == 2
            assert stats["store"]["n_files"] == 4
            assert set(stats["store"]["roots"]) == {"r0", "r1"}
            assert set(stats["server"]["roots"]) == {"r0", "r1"}
            # both roots actually hold data (consistent hashing spread 4
            # repos; collisions onto one root are possible but the chosen
            # ids split across roots — placement is deterministic)
            per_root_files = [s["n_files"]
                              for s in stats["store"]["roots"].values()]
            assert sum(per_root_files) == 4
            # admin fan-out hits every root
            status, _, body = c.post("/admin/gc")
            gc = json.loads(body)
            assert set(gc["roots"]) == {"r0", "r1"}
            status, _, body = c.get("/admin/fsck")
            assert json.loads(body)["ok"] is True
            # single-root selection
            status, _, body = c.post("/admin/compact?root=r1")
            assert "roots" in json.loads(body)
            status, _, body = c.post("/admin/gc?root=nope")
            assert status == 404
        finally:
            c.close()


def test_single_root_stats_keep_flat_shape(family_store):
    """Satellite fix: one root -> /stats keeps the flat single-store shape
    (server_smoke back-compat); no per-root nesting leaks in."""
    store, _ = family_store
    with ServerThread(store, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.get("/stats")
            stats = json.loads(body)
            assert "lifecycle" in stats["store"]          # flat summary
            assert "n_roots" not in stats["store"]
            assert "requests" in stats["server"]
            assert "roots" not in stats["server"]
            assert "http" in stats["server"]
            # flat admin reports too
            status, _, body = c.post("/admin/gc")
            assert "collected" in json.loads(body)
            assert "roots" not in json.loads(body)
        finally:
            c.close()


def test_put_with_declared_base_colocates_with_base_root(two_root_router,
                                                         tmp_path):
    """Family co-location: a new fine-tune declaring ?base= must land on
    the root serving that base (per-root delta domains), even when hash
    placement would pick the other root — and actually BitX-delta."""
    router = two_root_router
    rng = np.random.RandomState(31)
    base_path = str(tmp_path / "fam" / "model.safetensors")
    base = _write_model(base_path, rng)
    with ServerThread(router, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/fam/base/file/model.safetensors?sync=1",
                open(base_path, "rb").read())
            assert status == 200, body
            base_root = json.loads(body)["root"]
            # a fine-tune id that hash-places on the OTHER root
            other = next(f"fam/ft-{i}" for i in range(64)
                         if router.place(f"fam/ft-{i}") != base_root)
            ft_path = str(tmp_path / "famft" / "model.safetensors")
            _write_finetune(ft_path, base, rng)
            status, _, body = c.put(
                f"/repo/{other}/file/model.safetensors?base=fam/base&sync=1",
                open(ft_path, "rb").read())
            out = json.loads(body)
            assert status == 200, out
            assert out["root"] == base_root          # co-located
            assert out["job"]["results"][0]["base_id"] == "fam/base"
            assert out["job"]["results"][0]["n_bitx"] >= 1
        finally:
            c.close()


def test_reregistration_routes_to_owning_root(two_root_router, tmp_path):
    """A re-PUT of an existing repo must land on the root already holding
    it (not the hash placement), preserving the generation chain."""
    router = two_root_router
    rng = np.random.RandomState(5)
    p = str(tmp_path / "v1" / "model.safetensors")
    _write_model(p, rng, scale=1.0)
    # seed the repo on the NON-placement root directly
    rid = "org/displaced"
    anti = "r0" if router.place(rid) == "r1" else "r1"
    router.store(anti).ingest_file(p, rid)
    assert router.locate(rid) == anti

    p2 = str(tmp_path / "v2" / "model.safetensors")
    _write_model(p2, rng, scale=1.0)
    v2 = open(p2, "rb").read()
    with ServerThread(router, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(f"/repo/{rid}/file/model.safetensors"
                                    f"?sync=1", v2)
            assert status == 200, body
            status, _, got = c.get(f"/repo/{rid}/file/model.safetensors")
            assert status == 200 and got == v2
        finally:
            c.close()
    # the re-registration stayed on the owning root: two generations there,
    # nothing on the placement root
    assert len(router.store(anti).lifecycle.versions) == 2
    assert not router.store("r0" if anti == "r1" else "r1").file_index


# ---------------------------------------------------------------------------
# Conditional GETs: ETag / If-None-Match vs the key lifecycle
# ---------------------------------------------------------------------------

def test_conditional_get_lifecycle(family_store, tmp_path):
    """Tentpole acceptance: strong `key@gN` validators on files AND
    tensors, 304 revalidation (also on ranged requests — If-None-Match
    precedes Range per RFC 9110), gc leaving the validator alone, and a
    re-registration (new generation) turning the old ETag back into a
    200 with fresh bytes."""
    store, originals = family_store
    data = originals["org/base"]
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            path = "/repo/org/base/file/model.safetensors"
            status, h, body = c.get(path)
            assert status == 200 and body == data
            etag = h["etag"]
            gen = store.file_index["org/base/model.safetensors"]["gen"]
            assert etag == f'"org/base/model.safetensors@g{gen}"'
            assert h["cache-control"] == "no-cache"

            # revalidation: bodiless 304 echoing the validator
            status, h2, b2 = c.get(path, {"If-None-Match": etag})
            assert status == 304 and b2 == b"" and h2["etag"] == etag
            # weak comparison, list members and * all match
            assert c.get(path, {"If-None-Match": f'W/{etag}, "nope"'})[0] == 304
            assert c.get(path, {"If-None-Match": "*"})[0] == 304
            # a stale validator misses: full 200
            status, _, b3 = c.get(
                path, {"If-None-Match": '"org/base/model.safetensors@g999"'})
            assert status == 200 and b3 == data

            # If-None-Match is evaluated BEFORE Range: 304, never a 206
            status, _, b4 = c.get(path, {"If-None-Match": etag,
                                         "Range": "bytes=0-9"})
            assert status == 304 and b4 == b""

            # tensors share the file's (key, gen) validator — on the
            # decode path and on the sendfile (stored-codec) path alike
            status, th, _ = c.get("/repo/org/base/tensor/model.t0.weight")
            assert status == 200 and th["etag"] == etag
            assert c.get("/repo/org/base/tensor/model.t0.weight",
                         {"If-None-Match": etag})[0] == 304
            status, th2, _ = c.get("/repo/org/base/tensor/tok.table")
            assert status == 200 and th2["etag"] == etag
            status, th3, b5 = c.get("/repo/org/base/tensor/tok.table",
                                    {"If-None-Match": etag,
                                     "Range": "bytes=0-99"})
            assert status == 304 and b5 == b""

            # gc does not touch the record -> revalidation stays free
            c.post("/admin/gc")
            assert c.get(path, {"If-None-Match": etag})[0] == 304

            # re-register the key: new generation, old validator dead
            rng = np.random.RandomState(77)
            p2 = str(tmp_path / "v2" / "model.safetensors")
            _write_model(p2, rng, blob=True)
            v2 = open(p2, "rb").read()
            status, _, jb = c.put(path + "?sync=1", v2)
            assert status == 200, jb
            status, h5, b6 = c.get(path, {"If-None-Match": etag})
            assert status == 200 and b6 == v2, \
                "old ETag must MISS after re-registration"
            assert h5["etag"] != etag
            assert c.get(path, {"If-None-Match": h5["etag"]})[0] == 304
            # ... and while gc reclaims the superseded generation
            c.post("/admin/gc")
            status, _, b7 = c.get(path, {"If-None-Match": etag})
            assert status == 200 and b7 == v2

            assert srv.server.http["conditional_requests"] >= 10
            assert srv.server.http["not_modified"] >= 7
        finally:
            c.close()


def test_delete_kills_the_validator(family_store):
    """A deleted key stops emitting an ETag and stops revalidating."""
    store, originals = family_store
    with ServerThread(store, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            path = "/repo/u0/ft/file/model.safetensors"
            status, h, _ = c.get(path)
            assert status == 200
            etag = h["etag"]
            self_conn = c.conn  # DELETE via the same keep-alive connection
            self_conn.request("DELETE", path)
            r = self_conn.getresponse()
            assert r.status == 200 and json.loads(r.read())["deleted"] == 1
            status, h2, _ = c.get(path, {"If-None-Match": etag})
            assert status == 404 and "etag" not in h2
        finally:
            c.close()


# ---------------------------------------------------------------------------
# Two-tier decoded cache
# ---------------------------------------------------------------------------

def test_tiered_cache_spill_promote_and_purge(tmp_path):
    sd = str(tmp_path / "spill")
    cache = TieredResponseCache(sd, max_bytes=150, spill_max_bytes=4096,
                                max_items=8)
    a, b, d = b"A" * 60, b"B" * 60, b"D" * 60
    cache.put(("file", "r", "a"), "va", a, len(a))
    cache.put(("file", "r", "b"), "vb", b, len(b))
    cache.put(("file", "r", "d"), "vd", d, len(d))   # budget: evicts "a"
    st1 = cache.stats()
    assert st1["spilled_items"] >= 1 and cache.spill_bytes > 0
    assert len(os.listdir(sd)) == st1["spilled_items"]

    # disk hit promotes back into RAM and consumes the spill file
    assert cache.get(("file", "r", "a"), "va") == a
    st2 = cache.stats()
    assert st2["disk_hits"] == 1 and st2["promotions"] == 1
    assert cache.get(("file", "r", "a"), "va") == a   # now a RAM hit
    assert cache.stats()["hits"] >= 1

    # wrong validator is a miss on both tiers
    assert cache.get(("file", "r", "a"), "OTHER") is None

    # (bytes, meta) tuples — the tensor response shape — survive a
    # spill/promote round trip intact
    meta = {"dtype": "F32", "shape": [4, 2], "codec": "bitx"}
    cache.put(("tensor", "r", "f", "t"), "vt", (b"\x07" * 64, meta), 64)
    for i in range(4):  # push it out of RAM
        cache.put(("file", "r", f"x{i}"), f"v{i}", bytes([i]) * 60, 60)
    got = cache.get(("tensor", "r", "f", "t"), "vt")
    assert got == (b"\x07" * 64, meta)

    # purge drops dead entries from BOTH tiers without spilling them
    n = cache.purge(lambda objkey, validator: False)
    assert n >= 1 and len(cache) == 0
    assert cache.ram_bytes == 0 and cache.spill_bytes == 0
    assert os.listdir(sd) == []


def test_tiered_cache_spill_budget_and_cold_start_wipe(tmp_path):
    sd = str(tmp_path / "spill")
    cache = TieredResponseCache(sd, max_bytes=100, spill_max_bytes=300,
                                max_items=64)
    for i in range(8):  # each insert evicts the previous entry to disk
        cache.put(("file", "r", f"k{i}"), f"v{i}", bytes([i]) * 90, 90)
    assert cache.spill_bytes <= 300  # disk tier holds its own budget
    assert len(os.listdir(sd)) == cache.stats()["spilled_items"]
    # a new cache over the same directory starts cold: stale spill files
    # (another process's cache state) are wiped, not trusted
    again = TieredResponseCache(sd, max_bytes=100)
    assert os.listdir(sd) == [] and len(again) == 0


def test_fsck_cleans_decoded_spill_debris(tmp_path):
    """Crash debris contract: half-written `.part` temps under
    `.decoded/` are fsck orphans (removed under repair=True); finished
    spill files belong to a possibly-live engine and are left alone."""
    store = ZLLMStore(str(tmp_path / "s"), workers=0)
    droot = store.decoded_dir()
    part = os.path.join(droot, "deadbeef.dec.part")
    dec = os.path.join(droot, "cafecafe.dec")
    for p in (part, dec):
        with open(p, "wb") as f:
            f.write(b"torn")
    rep = store.fsck(repair=True, spot_check=0)
    assert rep.ok  # orphan debris never fails the check
    assert any(p.endswith(".part") for p in rep.orphans)
    assert not os.path.exists(part), "crash debris survived repair"
    assert os.path.exists(dec), "live spill file deleted by fsck"
    store.close()


def test_two_tier_cache_serves_spilled_tensor_byte_identical(family_store):
    """Tentpole acceptance: a tensor evicted from the RAM tier comes back
    from the decoded-spill tier byte-identical to `retrieve_tensor`,
    without re-paying the decode (single-flight leader count frozen)."""
    store, _ = family_store
    rec = store.file_index["u0/ft/model.safetensors"]
    reader = BitXReader.open(rec["path"])
    bitx_names = [r.name for r in reader.records if r.codec == "bitx"]
    reader.close()
    name = bitx_names[0]
    direct, _ = store.retrieve_tensor("u0/ft", "model.safetensors", name)

    # RAM tier sized to hold the tensor but NOT the full file: the file
    # GET must cascade the tensor entry onto the disk tier
    with ServerThread(store, max_concurrency=4,
                      cache_bytes=len(direct) + 1024,
                      spill_bytes=64 << 20) as srv:
        c = Client(srv)
        try:
            path = f"/repo/u0/ft/tensor/{name}"
            status, _, b1 = c.get(path)
            assert status == 200 and b1 == direct
            status, _, full = c.get("/repo/u0/ft/file/model.safetensors")
            assert status == 200
            cache = srv.server.engine._cache
            assert cache.stats()["spilled_items"] >= 1, \
                "file GET should have spilled the tensor entry to disk"
            leaders_before = srv.server.engine._flight.leaders
            status, _, b2 = c.get(path)
            assert status == 200 and b2 == direct == b1
            st = cache.stats()
            assert st["disk_hits"] >= 1 and st["promotions"] >= 1
            assert srv.server.engine._flight.leaders == leaders_before, \
                "promotion must not re-run the decode"
        finally:
            c.close()


# ---------------------------------------------------------------------------
# Bugfix regression: stale-generation cache leak
# ---------------------------------------------------------------------------

def test_slow_decode_outliving_its_generation_is_not_cached(tmp_path):
    """Regression (failing-first against the read_gen-keyed cache): a
    single-flight decode that completes AFTER its key is re-registered /
    deleted used to insert its result under the dead key, where it could
    never be hit and squatted on the byte budget until LRU pressure."""
    rng = np.random.RandomState(9)
    src = str(tmp_path / "hub" / "model.safetensors")
    _write_model(src, rng)
    blob = open(src, "rb").read()
    store = ZLLMStore(str(tmp_path / "store"), workers=0)
    store.ingest_file(src, "org/slow")

    release = threading.Event()
    in_flight = threading.Event()
    real = store.retrieve_file_digest

    def slow(repo_id, filename, verify=True):
        out = real(repo_id, filename, verify=verify)  # gate released here
        in_flight.set()
        release.wait(30)  # hold the flight open past the mutation
        return out

    store.retrieve_file_digest = slow

    async def scenario():
        engine = RetrievalEngine(store, max_concurrency=2,
                                 cache_bytes=1 << 20, spill_bytes=0)
        try:
            task = asyncio.ensure_future(
                engine.get_file_digest("org/slow", "model.safetensors"))
            await asyncio.get_running_loop().run_in_executor(
                None, in_flight.wait, 30)
            # the mutation lands mid-flight: key deleted, read_gen bumped
            store.delete_file("org/slow", "model.safetensors")
            release.set()
            data, _ = await task
            assert data == blob  # the in-flight caller still gets its bytes
            st = engine._cache.stats()
            assert st["items"] == 0 and st["ram_bytes"] == 0, \
                f"dead-generation bytes squat on the budget: {st}"
        finally:
            await engine.aclose()

    asyncio.run(scenario())
    store.close()


def test_gen_bump_purges_only_dead_entries(tmp_path):
    """The purge-on-gen-bump half of the fix — and the improvement over
    the old whole-cache wipe: a mutation of key A reclaims A's bytes
    immediately while key B's hot entry survives."""
    rng = np.random.RandomState(10)
    store = ZLLMStore(str(tmp_path / "store"), workers=0)
    blobs = {}
    for repo in ("org/a", "org/b"):
        p = str(tmp_path / repo.replace("/", "_") / "model.safetensors")
        _write_model(p, rng)
        store.ingest_file(p, repo)
        blobs[repo] = open(p, "rb").read()

    async def scenario():
        engine = RetrievalEngine(store, max_concurrency=2,
                                 cache_bytes=1 << 20, spill_bytes=0)
        try:
            for repo in blobs:
                data, _ = await engine.get_file_digest(repo,
                                                       "model.safetensors")
                assert data == blobs[repo]
            assert engine._cache.stats()["items"] == 2
            both = engine._cache.ram_bytes
            store.delete_file("org/a", "model.safetensors")  # bumps read_gen
            # next access observes the bump and purges ONLY the dead entry
            data, _ = await engine.get_file_digest("org/b",
                                                   "model.safetensors")
            assert data == blobs["org/b"]
            st = engine._cache.stats()
            assert st["purged"] == 1 and st["items"] == 1
            assert engine._cache.ram_bytes == both - len(blobs["org/a"])
        finally:
            await engine.aclose()

    asyncio.run(scenario())
    store.close()
