"""HTTP/1.1 serving surface: keep-alive, range GETs, the remote write
path, zero-copy sendfile and the multi-store router.

Covers the PR's serving acceptance criteria: suffix/out-of-bounds/multi
range semantics (206 / 416 / 200-full fallback), range over a BitX-delta
tensor byte-identical to slicing the full GET, connection reuse across
requests, PUT → spooled ingest job → ranged read-back against a routed
2-root server, and /stats keeping the flat single-root shape while
aggregating per-root under a router.
"""

import json
import http.client
import os
import time
from collections import OrderedDict

import numpy as np
import pytest

from repro.core.bitx import BitXReader
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st
from repro.serve.router import StoreRouter
from repro.serve.store_server import ServerThread, parse_byte_range


def _write_model(path, rng, n_tensors=3, n=2048, scale=0.02, blob=False):
    tensors = {f"model.t{i}.weight": (rng.randn(n) * scale).astype(np.float32)
               for i in range(n_tensors)}
    if blob:  # incompressible non-float payload -> `stored` codec on disk
        tensors["tok.table"] = np.frombuffer(rng.bytes(32768), np.uint8).copy()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path)
    return tensors


def _write_finetune(path, base_tensors, rng, sigma=1e-3):
    ft = {k: ((v + rng.randn(*v.shape).astype(np.float32) * sigma)
              .astype(np.float32) if v.dtype.kind == "f" else v.copy())
          for k, v in base_tensors.items()}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(ft, path)
    return ft


class Client:
    """Thin keep-alive HTTP client: one connection, many requests."""

    def __init__(self, srv):
        self.conn = http.client.HTTPConnection(srv.host, srv.port, timeout=60)

    def get(self, path, headers=None):
        self.conn.request("GET", path, headers=headers or {})
        r = self.conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()

    def put(self, path, body):
        self.conn.request("PUT", path, body=body)
        r = self.conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()

    def post(self, path, body=b""):
        self.conn.request("POST", path, body=body)
        r = self.conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()

    def close(self):
        self.conn.close()


@pytest.fixture
def family_store(tmp_path):
    """Base + BitX fine-tune + an incompressible (`stored`) tensor."""
    rng = np.random.RandomState(42)
    base_path = str(tmp_path / "hub" / "org" / "base" / "model.safetensors")
    base = _write_model(base_path, rng, blob=True)
    ft_path = str(tmp_path / "hub" / "u0" / "ft" / "model.safetensors")
    _write_finetune(ft_path, base, rng)
    store = ZLLMStore(str(tmp_path / "store"), workers=2)
    store.ingest_file(base_path, "org/base")
    store.ingest_file(ft_path, "u0/ft", declared_base="org/base")
    yield store, {"org/base": open(base_path, "rb").read(),
                  "u0/ft": open(ft_path, "rb").read()}
    store.close()


# ---------------------------------------------------------------------------
# Range parser unit coverage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("header,size,expect", [
    (None, 100, None),
    ("bytes=0-9", 100, (0, 9)),
    ("bytes=10-", 100, (10, 99)),
    ("bytes=-10", 100, (90, 99)),
    ("bytes=-200", 100, (0, 99)),        # oversized suffix clamps to all
    ("bytes=0-500", 100, (0, 99)),       # end clamps to size-1
    ("bytes=100-", 100, "unsat"),        # first-pos at EOF
    ("bytes=-0", 100, "unsat"),          # empty suffix
    ("bytes=-5", 0, "unsat"),            # empty body
    ("bytes=0-1,4-5", 100, None),        # multi-range -> full fallback
    ("bytes=5-2", 100, None),            # inverted -> full fallback
    ("bytes=abc", 100, None),
    ("chars=0-5", 100, None),
])
def test_parse_byte_range(header, size, expect):
    assert parse_byte_range(header, size) == expect


# ---------------------------------------------------------------------------
# Range GETs over HTTP
# ---------------------------------------------------------------------------

def test_file_range_semantics(family_store):
    store, originals = family_store
    data = originals["org/base"]
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            path = "/repo/org/base/file/model.safetensors"
            status, headers, body = c.get(path)
            assert status == 200 and body == data
            assert headers["accept-ranges"] == "bytes"

            status, headers, body = c.get(path, {"Range": "bytes=100-299"})
            assert status == 206 and body == data[100:300]
            assert headers["content-range"] == f"bytes 100-299/{len(data)}"

            status, _, body = c.get(path, {"Range": "bytes=-64"})
            assert status == 206 and body == data[-64:]

            status, headers, body = c.get(
                path, {"Range": f"bytes={len(data)}-{len(data) + 10}"})
            assert status == 416
            assert headers["content-range"] == f"bytes */{len(data)}"

            # multi-range: deliberate 200-full fallback
            status, _, body = c.get(path, {"Range": "bytes=0-1,10-11"})
            assert status == 200 and body == data
        finally:
            c.close()


def test_bitx_tensor_range_matches_full_get_slice(family_store):
    """Satellite acceptance: a range over a BitX-delta tensor must be
    byte-identical to slicing the full GET (and the direct store read)."""
    store, _ = family_store
    # pick a tensor the fine-tune actually stored as a BitX delta
    rec = store.file_index["u0/ft/model.safetensors"]
    reader = BitXReader.open(rec["path"])
    bitx_names = [r.name for r in reader.records if r.codec == "bitx"]
    reader.close()
    assert bitx_names, "fixture must produce at least one BitX record"
    name = bitx_names[0]
    direct, meta = store.retrieve_tensor("u0/ft", "model.safetensors", name)

    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            path = f"/repo/u0/ft/tensor/{name}"
            status, headers, full = c.get(path)
            assert status == 200 and full == direct
            assert headers["x-tensor-codec"] == "bitx"
            n = len(full)
            for rng_hdr, lo, hi in [("bytes=0-99", 0, 100),
                                    (f"bytes={n // 2}-", n // 2, n),
                                    ("bytes=-128", n - 128, n),
                                    (f"bytes=7-{n + 999}", 7, n)]:
                status, _, part = c.get(path, {"Range": rng_hdr})
                assert status == 206
                assert part == full[lo:hi] == direct[lo:hi]
            # the decode ran once per read generation: every slice above
            # was cut from the cached buffer, not re-decoded
            sf = srv.server.engine.stats()["singleflight"]
            assert sf["leaders"] <= 2  # one file decode path + one tensor
        finally:
            c.close()


def test_stored_tensor_served_via_sendfile(family_store):
    store, _ = family_store
    direct, meta = store.retrieve_tensor("org/base", "model.safetensors",
                                         "tok.table")
    assert meta["codec"] == "stored"
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            path = "/repo/org/base/tensor/tok.table"
            status, headers, full = c.get(path)
            assert status == 200 and full == direct
            assert headers["x-zllm-sendfile"] == "1"
            assert headers["x-tensor-codec"] == "stored"
            status, headers, part = c.get(path, {"Range": "bytes=1000-1999"})
            assert status == 206 and part == direct[1000:2000]
            assert headers["x-zllm-sendfile"] == "1"
            status, headers, _ = c.get(path,
                                       {"Range": f"bytes={len(direct)}-"})
            assert status == 416
            assert srv.server.http["sendfile_responses"] >= 2
        finally:
            c.close()


def test_keepalive_connection_reuse(family_store):
    store, originals = family_store
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            for _ in range(16):
                status, headers, _ = c.get("/healthz")
                assert status == 200
                assert headers["connection"] == "keep-alive"
            status, _, body = c.get("/repo/org/base/file/model.safetensors")
            assert status == 200 and body == originals["org/base"]
        finally:
            c.close()
        # 17+ requests, exactly one connection
        assert srv.server.http["requests"] >= 17
        assert srv.server.http["connections"] == 1


# ---------------------------------------------------------------------------
# Remote write path
# ---------------------------------------------------------------------------

def test_put_sync_then_read_back(family_store, tmp_path):
    store, _ = family_store
    rng = np.random.RandomState(7)
    p = str(tmp_path / "new" / "model.safetensors")
    _write_model(p, rng, scale=1.0)
    data = open(p, "rb").read()
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/org/new/file/model.safetensors?sync=1", data)
            out = json.loads(body)
            assert status == 200 and out["job"]["state"] == "done", out
            res = out["job"]["results"][0]
            assert res["repo_id"] == "org/new" and res["raw_bytes"] == len(data)
            status, _, got = c.get("/repo/org/new/file/model.safetensors")
            assert status == 200 and got == data
            # the spool was cleaned up after the job finished
            assert os.listdir(store.spool_dir()) == []
        finally:
            c.close()


def test_put_async_job_lifecycle_and_declared_base(family_store, tmp_path):
    """Async PUT: 202 + job id, /admin/jobs reaches `done`, the declared
    base (?base=) produces BitX records, and the result is bit-exact."""
    store, originals = family_store
    rng = np.random.RandomState(11)
    base_tensors = st.load_file(
        str(tmp_path / "hub" / "org" / "base" / "model.safetensors"))
    p = str(tmp_path / "ft2" / "model.safetensors")
    _write_finetune(p, base_tensors, rng)
    data = open(p, "rb").read()
    with ServerThread(store, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/u1/ft2/file/model.safetensors?base=org/base", data)
            out = json.loads(body)
            assert status == 202 and "job_id" in out, out
            deadline = time.time() + 60
            while True:
                status, _, body = c.get(f"/admin/jobs?job={out['job_id']}")
                job = json.loads(body)
                if job["state"] in ("done", "failed"):
                    break
                assert time.time() < deadline, job
                time.sleep(0.02)
            assert job["state"] == "done", job
            assert job["results"][0]["base_id"] == "org/base"
            assert job["results"][0]["n_bitx"] >= 1
            status, _, got = c.get("/repo/u1/ft2/file/model.safetensors")
            assert status == 200 and got == data
            # job listing includes the finished job
            status, _, body = c.get("/admin/jobs")
            assert any(j["job_id"] == out["job_id"]
                       for j in json.loads(body)["jobs"])
        finally:
            c.close()
    assert store.fsck(spot_check=2).ok


def test_put_base_survives_restart_and_serves_finetunes(tmp_path):
    """Regression: the job worker must adopt a spooled BASE into
    basecache/ BEFORE persisting the index — a restarted store must not
    resurrect a dead spool path in base_paths/families (which would make
    every later same-family ingest fail at the bit-distance matcher)."""
    rng = np.random.RandomState(21)
    base_path = str(tmp_path / "hub" / "model.safetensors")
    base = _write_model(base_path, rng)
    root = str(tmp_path / "store")
    store = ZLLMStore(root, workers=2)
    with ServerThread(store, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/org/base/file/model.safetensors?sync=1",
                open(base_path, "rb").read())
            assert status == 200, body
        finally:
            c.close()
    store.close()

    # fresh process: every persisted base path must exist on disk, and a
    # declared-base fine-tune must still delta against the adopted base
    store2 = ZLLMStore(root, workers=2)
    assert store2.load_index()
    for bid, p in store2.base_paths.items():
        assert os.path.exists(p), f"base path for {bid} rotted: {p}"
    ft_path = str(tmp_path / "ft" / "model.safetensors")
    _write_finetune(ft_path, base, rng)
    res = store2.ingest_file(ft_path, "u9/ft", declared_base="org/base")
    assert res.base_id == "org/base" and res.n_bitx >= 1
    assert store2.retrieve_file("u9/ft", "model.safetensors") == \
        open(ft_path, "rb").read()
    store2.close()


def test_corrupt_stored_span_is_never_served(family_store):
    """verify=True must cover the sendfile path too: flip a byte inside a
    stored-codec span on disk — the span check fails, the decode path
    takes over, and ITS verification turns the rot into a 500 (never a
    silent 200 of corrupt bytes)."""
    store, _ = family_store
    cpath, off, ln, meta = store.tensor_sendfile_span(
        "org/base", "model.safetensors", "tok.table")
    with open(cpath, "r+b") as f:
        f.seek(off + 7)
        orig = f.read(1)
        f.seek(off + 7)
        f.write(bytes([orig[0] ^ 0xFF]))
    with ServerThread(store, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, headers, body = c.get("/repo/org/base/tensor/tok.table")
            assert status == 500, (status, headers)
            assert "x-zllm-sendfile" not in headers
            assert srv.server.http["sendfile_responses"] == 0
        finally:
            c.close()


def test_put_without_content_length_is_rejected(family_store):
    store, _ = family_store
    with ServerThread(store, max_concurrency=2) as srv:
        import socket
        s = socket.create_connection((srv.host, srv.port), timeout=30)
        try:
            s.sendall(b"PUT /repo/a/b/file/f HTTP/1.1\r\n"
                      b"transfer-encoding: chunked\r\n\r\n")
            resp = s.recv(4096)
            assert b"411" in resp.split(b"\r\n", 1)[0]
        finally:
            s.close()


# ---------------------------------------------------------------------------
# Multi-store router
# ---------------------------------------------------------------------------

@pytest.fixture
def two_root_router(tmp_path):
    s0 = ZLLMStore(str(tmp_path / "r0"), workers=2)
    s1 = ZLLMStore(str(tmp_path / "r1"), workers=2)
    router = StoreRouter(OrderedDict([("r0", s0), ("r1", s1)]))
    yield router
    router.close()


def test_router_placement_is_deterministic_and_spreads(two_root_router):
    router = two_root_router
    placed = {router.place(f"org/model-{i}") for i in range(64)}
    assert placed == {"r0", "r1"}          # both roots get keys
    for i in range(16):
        rid = f"org/model-{i}"
        assert router.place(rid) == router.place(rid)


def test_router_put_get_and_aggregated_stats(two_root_router, tmp_path):
    router = two_root_router
    rng = np.random.RandomState(3)
    payloads = {}
    for i in range(4):
        p = str(tmp_path / f"m{i}" / "model.safetensors")
        _write_model(p, rng, scale=1.0)
        payloads[f"org/m{i}"] = open(p, "rb").read()

    with ServerThread(router, max_concurrency=4) as srv:
        c = Client(srv)
        try:
            for rid, data in payloads.items():
                status, _, body = c.put(f"/repo/{rid}/file/model.safetensors"
                                        f"?sync=1", data)
                assert status == 200, body
            # reads route to whichever root holds the repo
            for rid, data in payloads.items():
                status, _, got = c.get(f"/repo/{rid}/file/model.safetensors")
                assert status == 200 and got == data
                # ranged read through the router too
                status, _, part = c.get(f"/repo/{rid}/file/model.safetensors",
                                        {"Range": "bytes=32-95"})
                assert status == 206 and part == data[32:96]
            status, _, body = c.get("/stats")
            stats = json.loads(body)
            # aggregated multi-root shape
            assert stats["store"]["n_roots"] == 2
            assert stats["store"]["n_files"] == 4
            assert set(stats["store"]["roots"]) == {"r0", "r1"}
            assert set(stats["server"]["roots"]) == {"r0", "r1"}
            # both roots actually hold data (consistent hashing spread 4
            # repos; collisions onto one root are possible but the chosen
            # ids split across roots — placement is deterministic)
            per_root_files = [s["n_files"]
                              for s in stats["store"]["roots"].values()]
            assert sum(per_root_files) == 4
            # admin fan-out hits every root
            status, _, body = c.post("/admin/gc")
            gc = json.loads(body)
            assert set(gc["roots"]) == {"r0", "r1"}
            status, _, body = c.get("/admin/fsck")
            assert json.loads(body)["ok"] is True
            # single-root selection
            status, _, body = c.post("/admin/compact?root=r1")
            assert "roots" in json.loads(body)
            status, _, body = c.post("/admin/gc?root=nope")
            assert status == 404
        finally:
            c.close()


def test_single_root_stats_keep_flat_shape(family_store):
    """Satellite fix: one root -> /stats keeps the flat single-store shape
    (server_smoke back-compat); no per-root nesting leaks in."""
    store, _ = family_store
    with ServerThread(store, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.get("/stats")
            stats = json.loads(body)
            assert "lifecycle" in stats["store"]          # flat summary
            assert "n_roots" not in stats["store"]
            assert "requests" in stats["server"]
            assert "roots" not in stats["server"]
            assert "http" in stats["server"]
            # flat admin reports too
            status, _, body = c.post("/admin/gc")
            assert "collected" in json.loads(body)
            assert "roots" not in json.loads(body)
        finally:
            c.close()


def test_put_with_declared_base_colocates_with_base_root(two_root_router,
                                                         tmp_path):
    """Family co-location: a new fine-tune declaring ?base= must land on
    the root serving that base (per-root delta domains), even when hash
    placement would pick the other root — and actually BitX-delta."""
    router = two_root_router
    rng = np.random.RandomState(31)
    base_path = str(tmp_path / "fam" / "model.safetensors")
    base = _write_model(base_path, rng)
    with ServerThread(router, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(
                "/repo/fam/base/file/model.safetensors?sync=1",
                open(base_path, "rb").read())
            assert status == 200, body
            base_root = json.loads(body)["root"]
            # a fine-tune id that hash-places on the OTHER root
            other = next(f"fam/ft-{i}" for i in range(64)
                         if router.place(f"fam/ft-{i}") != base_root)
            ft_path = str(tmp_path / "famft" / "model.safetensors")
            _write_finetune(ft_path, base, rng)
            status, _, body = c.put(
                f"/repo/{other}/file/model.safetensors?base=fam/base&sync=1",
                open(ft_path, "rb").read())
            out = json.loads(body)
            assert status == 200, out
            assert out["root"] == base_root          # co-located
            assert out["job"]["results"][0]["base_id"] == "fam/base"
            assert out["job"]["results"][0]["n_bitx"] >= 1
        finally:
            c.close()


def test_reregistration_routes_to_owning_root(two_root_router, tmp_path):
    """A re-PUT of an existing repo must land on the root already holding
    it (not the hash placement), preserving the generation chain."""
    router = two_root_router
    rng = np.random.RandomState(5)
    p = str(tmp_path / "v1" / "model.safetensors")
    _write_model(p, rng, scale=1.0)
    # seed the repo on the NON-placement root directly
    rid = "org/displaced"
    anti = "r0" if router.place(rid) == "r1" else "r1"
    router.store(anti).ingest_file(p, rid)
    assert router.locate(rid) == anti

    p2 = str(tmp_path / "v2" / "model.safetensors")
    _write_model(p2, rng, scale=1.0)
    v2 = open(p2, "rb").read()
    with ServerThread(router, max_concurrency=2) as srv:
        c = Client(srv)
        try:
            status, _, body = c.put(f"/repo/{rid}/file/model.safetensors"
                                    f"?sync=1", v2)
            assert status == 200, body
            status, _, got = c.get(f"/repo/{rid}/file/model.safetensors")
            assert status == 200 and got == v2
        finally:
            c.close()
    # the re-registration stayed on the owning root: two generations there,
    # nothing on the placement root
    assert len(router.store(anti).lifecycle.versions) == 2
    assert not router.store("r0" if anti == "r1" else "r1").file_index
