"""Entropy-backend compatibility (repro.core.zstd_compat).

A ``.bitx`` container stamps the backend that wrote it (``zstd`` or the
``zlib`` fallback). Frames from the two are NOT interchangeable, so opening
a container under the other backend must raise a clear, actionable error —
never hand back garbage bytes. These tests run on both CI matrix legs: each
leg writes with ITS backend and forges the other stamp, so the
zstd-container-in-zlib-env case and its mirror are both exercised.
"""

import json
import os
import struct

import numpy as np
import pytest

from repro.core import zstd_compat as zstd
from repro.core.bitx import MAGIC, BitXReader, BitXWriter
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st

OTHER_BACKEND = "zlib" if zstd.BACKEND == "zstd" else "zstd"


def _restamp_backend(path: str, backend: str) -> None:
    """Rewrite a container's header with a forged entropy-backend stamp
    (payload untouched) — simulating a container produced in an env with
    the other backend installed."""
    raw = open(path, "rb").read()
    assert raw[:8] == MAGIC
    (hlen,) = struct.unpack("<Q", raw[8:16])
    header = json.loads(raw[16:16 + hlen])
    header["backend"] = backend
    hjson = json.dumps(header, separators=(",", ":")).encode()
    with open(path, "wb") as f:
        f.write(MAGIC + struct.pack("<Q", len(hjson)) + hjson + raw[16 + hlen:])


def _write_container(tmp_path) -> str:
    rng = np.random.RandomState(3)
    w = BitXWriter()
    w.add_zipnn("t0", "F32", (512,), rng.randn(512).astype(np.float32), "h0")
    path = str(tmp_path / "c.bitx")
    w.write(path)
    return path


def test_same_backend_roundtrip(tmp_path):
    path = _write_container(tmp_path)
    r = BitXReader.open(path)
    assert r.file_metadata == {}
    out = r.decode_tensor(0, None, None)
    assert out.shape == (512,)
    r.close()


def test_backend_mismatch_raises_clear_error(tmp_path):
    path = _write_container(tmp_path)
    _restamp_backend(path, OTHER_BACKEND)
    with pytest.raises(ValueError) as ei:
        BitXReader.open(path)
    msg = str(ei.value)
    # the error must name both backends and point at the shim
    assert OTHER_BACKEND in msg and zstd.BACKEND in msg
    assert "zstd_compat" in msg


def test_store_retrieval_surfaces_backend_mismatch_not_garbage(tmp_path):
    """End to end: a store whose container is stamped for the other backend
    must raise the clear error from retrieve_file/retrieve_tensor AND from
    a fresh process's load_index path — never decode garbage."""
    d = str(tmp_path / "hub" / "org" / "m")
    os.makedirs(d, exist_ok=True)
    rng = np.random.RandomState(5)
    st.save_file({"model.t0.weight": rng.randn(1024).astype(np.float32)},
                 os.path.join(d, "model.safetensors"))
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_repo(d, "org/m")
    store.save_index()
    cpath = store.file_index["org/m/model.safetensors"]["path"]
    store.close()

    _restamp_backend(cpath, OTHER_BACKEND)
    s2 = ZLLMStore(str(tmp_path / "store"))
    assert s2.load_index()
    with pytest.raises(ValueError, match="entropy backend"):
        s2.retrieve_file("org/m", "model.safetensors")
    with pytest.raises(ValueError, match="entropy backend"):
        s2.retrieve_tensor("org/m", "model.safetensors", "model.t0.weight")
    # fsck flags it as unreadable rather than crashing
    report = s2.fsck(repair=False, spot_check=None)
    assert any("unreadable container" in msg for _, msg in report.corrupt)
    s2.close()
