"""Parallel ingest/retrieval engine tests: bit-identical containers across
worker counts, single-hash-pass base maps, cache invalidation, persistence
of tensor-dedup state, and fresh-process retrieval."""

import hashlib
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.bitx import BitXCodec, BitXReader, BitXWriter
from repro.core.dedup import FileDedup, sha256_file
from repro.core import pipeline as pipeline_mod
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st

# src/ directory (repro may be a namespace package, so derive from a module)
SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(pipeline_mod.__file__))))


def _write_model(path, rng, n_tensors=6, n=2048, scale=0.02):
    tensors = {f"model.t{i}.weight": (rng.randn(n) * scale).astype(np.float32)
               for i in range(n_tensors)}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path)
    return tensors


def _write_finetune(path, base_tensors, rng, sigma=1e-3):
    ft = {k: (v + rng.randn(*v.shape).astype(np.float32) * sigma).astype(np.float32)
          for k, v in base_tensors.items()}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(ft, path)
    return ft


def _container_bytes(store_root):
    out = {}
    croot = os.path.join(store_root, "containers")
    for dirpath, _, files in os.walk(croot):
        for fn in files:
            p = os.path.join(dirpath, fn)
            out[os.path.relpath(p, croot)] = open(p, "rb").read()
    return out


# ---------------------------------------------------------------------------
# Tentpole: parallel == serial, bit for bit
# ---------------------------------------------------------------------------

def test_parallel_ingest_bit_identical_to_serial(tmp_path, corpus_dir):
    """Same corpus through workers∈{1,4} ⇒ byte-identical .bitx containers
    (the ordered-merge determinism rule), and bit-exact retrieval."""
    root, manifest = corpus_dir
    stores = {}
    for w in (1, 4):
        s = ZLLMStore(str(tmp_path / f"store-w{w}"), workers=w)
        for rid, kind in manifest:
            s.ingest_repo(os.path.join(root, rid), rid)
        stores[w] = s

    c1 = _container_bytes(str(tmp_path / "store-w1"))
    c4 = _container_bytes(str(tmp_path / "store-w4"))
    assert c1.keys() == c4.keys() and len(c1) > 0
    for name in c1:
        assert c1[name] == c4[name], f"container diverged: {name}"

    # parallel retrieval reconstructs bit-exactly (verify=True checks sha256)
    for rid, kind in manifest:
        orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
        assert stores[4].retrieve_file(rid, "model.safetensors") == orig
    for s in stores.values():
        s.close()


def test_parallel_stats_match_serial(tmp_path, corpus_dir):
    root, manifest = corpus_dir
    summaries = {}
    for w in (1, 4):
        s = ZLLMStore(str(tmp_path / f"stat-w{w}"), workers=w)
        for rid, kind in manifest:
            s.ingest_repo(os.path.join(root, rid), rid)
        summaries[w] = s.summary()
        s.close()
    for key in ("raw_bytes", "stored_bytes", "reduction_ratio", "file_dedup_hits",
                "tensor_dedup"):
        assert summaries[1][key] == summaries[4][key], key


def test_pipelined_multifile_bit_identical_to_serial(tmp_path, corpus_dir):
    """The multi-file extension of the workers-1-vs-4 equivalence: the whole
    corpus through ONE cross-file pipelined ingest_many batch (stage A
    prefetch + deferred writes) must produce byte-identical containers to
    per-file serial ingest, with results in submission order."""
    root, manifest = corpus_dir
    uploads = [(os.path.join(root, rid, "model.safetensors"), rid)
               for rid, _ in manifest]

    s_serial = ZLLMStore(str(tmp_path / "serial"), workers=1)
    serial_results = [s_serial.ingest_file(p, rid) for p, rid in uploads]

    s_pipe = ZLLMStore(str(tmp_path / "pipe"), workers=4, pipeline_depth=3)
    pipe_results = s_pipe.ingest_many(uploads)

    c1 = _container_bytes(str(tmp_path / "serial"))
    c2 = _container_bytes(str(tmp_path / "pipe"))
    assert c1.keys() == c2.keys() and len(c1) > 0
    for name in c1:
        assert c1[name] == c2[name], f"pipelined container diverged: {name}"

    # per-upload decisions match in submission order
    assert len(pipe_results) == len(serial_results)
    for rs, rp in zip(serial_results, pipe_results):
        for f in ("repo_id", "filename", "raw_bytes", "stored_bytes",
                  "file_dedup_hit", "near_dup_hit", "base_id", "n_tensors",
                  "n_dedup", "n_bitx", "n_zipnn", "n_raw"):
            assert getattr(rs, f) == getattr(rp, f), f
    # aggregate stats and retrieval match too
    for key in ("raw_bytes", "stored_bytes", "reduction_ratio",
                "file_dedup_hits", "near_dup_hits", "tensor_dedup"):
        assert s_serial.summary()[key] == s_pipe.summary()[key], key
    for p, rid in uploads:
        assert s_pipe.retrieve_file(rid, "model.safetensors") == open(p, "rb").read()
    s_serial.close()
    s_pipe.close()


def test_ingest_repos_cross_repo_pipeline_matches_per_repo(tmp_path, corpus_dir):
    root, manifest = corpus_dir
    s_a = ZLLMStore(str(tmp_path / "per-repo"), workers=1)
    for rid, _ in manifest:
        s_a.ingest_repo(os.path.join(root, rid), rid)
    s_b = ZLLMStore(str(tmp_path / "cross"), workers=4)
    s_b.ingest_repos([(os.path.join(root, rid), rid) for rid, _ in manifest])
    ca, cb = _container_bytes(str(tmp_path / "per-repo")), _container_bytes(str(tmp_path / "cross"))
    assert ca.keys() == cb.keys() and all(ca[k] == cb[k] for k in ca)
    s_a.close()
    s_b.close()


def test_process_entropy_backend_bit_identical(tmp_path):
    """Opt-in ProcessPoolExecutor entropy stage: same containers, bit for
    bit, as the in-thread entropy path (frames are pure functions of
    bytes/level/threads). Skips nothing: if fork is unavailable the store
    degrades to threads and the assertion still holds."""
    rng = np.random.RandomState(21)
    base_dir = str(tmp_path / "hub" / "org" / "b")
    base = _write_model(os.path.join(base_dir, "model.safetensors"), rng,
                        n_tensors=4, n=65536 // 4)
    ft_dir = str(tmp_path / "hub" / "u" / "ft")
    _write_finetune(os.path.join(ft_dir, "model.safetensors"), base, rng)
    uploads = [(os.path.join(base_dir, "model.safetensors"), "org/b"),
               (os.path.join(ft_dir, "model.safetensors"), "u/ft")]

    s_thread = ZLLMStore(str(tmp_path / "threads"), workers=2)
    s_thread.ingest_many(uploads)
    s_proc = ZLLMStore(str(tmp_path / "procs"), workers=2, entropy_procs=2)
    s_proc.ingest_many(uploads)

    ct = _container_bytes(str(tmp_path / "threads"))
    cp = _container_bytes(str(tmp_path / "procs"))
    assert ct.keys() == cp.keys() and len(ct) == 2
    for name in ct:
        assert ct[name] == cp[name], f"entropy-procs container diverged: {name}"
    s_thread.close()
    s_proc.close()


def test_pipelined_write_failure_rolls_back_cleanly(tmp_path, monkeypatch):
    """A failed deferred container write must not leave the index pointing
    at a container that never landed: the batch raises, the failed upload's
    decisions are rolled back — including a later upload that whole-file-
    dedup'd against the failed container — earlier uploads stay
    retrievable, fsck is clean."""
    import shutil
    import time as time_mod
    rng = np.random.RandomState(31)
    dirs = []
    for i in range(3):
        d = str(tmp_path / "hub" / f"org{i}" / "m")
        _write_model(os.path.join(d, "model.safetensors"),
                     np.random.RandomState(100 + i), scale=1.0)
        dirs.append(d)
    # upload 3: byte-identical to upload 1 → file-dedup pin against the
    # container whose write is about to fail
    dup_dir = str(tmp_path / "hub" / "org3" / "m")
    os.makedirs(dup_dir, exist_ok=True)
    shutil.copyfile(os.path.join(dirs[1], "model.safetensors"),
                    os.path.join(dup_dir, "model.safetensors"))
    dirs.append(dup_dir)
    uploads = [(os.path.join(d, "model.safetensors"), f"org{i}/m")
               for i, d in enumerate(dirs)]

    store = ZLLMStore(str(tmp_path / "store"), workers=2, pipeline_depth=2)
    from repro.core.bitx import BitXWriter
    real_write = BitXWriter.write
    calls = []

    def failing_write(self, path):
        calls.append(path)
        if len(calls) == 2:  # second container write blows up (disk full);
            # the sleep lets the decision stage reach the dedup upload first
            time_mod.sleep(0.5)
            raise OSError(28, "No space left on device")
        return real_write(self, path)

    monkeypatch.setattr(BitXWriter, "write", failing_write)
    with pytest.raises(OSError):
        store.ingest_many(uploads)
    monkeypatch.setattr(BitXWriter, "write", real_write)

    # upload 0 committed; 1 (failed), 2 (poisoned suffix) and 3 (dedup pin
    # into the failed container) all rolled back
    assert "org0/m/model.safetensors" in store.file_index
    for i in (1, 2, 3):
        assert f"org{i}/m/model.safetensors" not in store.file_index, i
    assert len(store.results) == store.stats.n_files == 1
    assert store.retrieve_file("org0/m", "model.safetensors") == \
        open(uploads[0][0], "rb").read()
    report = store.fsck(repair=False, spot_check=None)
    assert report.ok and not report.orphans, report.summary()
    # the rolled-back uploads re-ingest cleanly afterwards; the dup now
    # dedups against upload 1's NEW (successful) container
    res = store.ingest_many(uploads[1:])
    assert [r.file_dedup_hit for r in res] == [False, False, True]
    for p, rid in uploads[1:]:
        assert store.retrieve_file(rid, "model.safetensors") == open(p, "rb").read()
    store.close()


def test_gc_during_ingest_batch_serializes_safely(tmp_path):
    """gc()/delete from another thread during an ingest batch must
    serialize behind the admin lock — never corrupt index/lifecycle state
    mid-decision."""
    import threading
    paths = []
    for i in range(6):
        p = str(tmp_path / "hub" / f"org{i}" / "m" / "model.safetensors")
        _write_model(p, np.random.RandomState(300 + i), scale=1.0)
        paths.append((p, f"org{i}/m"))
    store = ZLLMStore(str(tmp_path / "store"), workers=2, pipeline_depth=2)
    store.ingest_file(*paths[0])
    store.delete_repo("org0")          # something for gc to reclaim

    sweeps = []
    t = threading.Thread(target=lambda: sweeps.append(store.gc()))
    t.start()                          # races the batch below for the lock
    store.ingest_many(paths[1:])
    t.join(timeout=60)
    assert sweeps and sweeps[0]["collected"] in (0, 1)
    store.gc()                         # idempotent follow-up sweep
    for p, rid in paths[1:]:
        assert store.retrieve_file(rid, "model.safetensors") == open(p, "rb").read()
    report = store.fsck(repair=False, spot_check=None)
    assert report.ok and not report.orphans, report.summary()
    store.close()


def test_failed_batch_reregistering_key_twice_leaves_no_dangling_entry(
        tmp_path, monkeypatch):
    """Regression (found in review): a batch that ingests the SAME key twice
    and fails must not 'restore' the second upload's index entry to the
    first upload's generation — that generation was rolled back moments
    earlier. The key must simply vanish and the bytes re-ingest cleanly."""
    v1_path = str(tmp_path / "v1" / "model.safetensors")
    v2_path = str(tmp_path / "v2" / "model.safetensors")
    _write_model(v1_path, np.random.RandomState(51), scale=1.0)
    _write_model(v2_path, np.random.RandomState(52), scale=1.0)
    v1 = open(v1_path, "rb").read()

    store = ZLLMStore(str(tmp_path / "store"), workers=2, pipeline_depth=2)
    from repro.core.bitx import BitXWriter
    monkeypatch.setattr(BitXWriter, "write",
                        lambda self, path: (_ for _ in ()).throw(
                            OSError(28, "No space left on device")))
    with pytest.raises(OSError):
        store.ingest_many([(v1_path, "org/m"), (v2_path, "org/m")])
    monkeypatch.undo()

    assert "org/m/model.safetensors" not in store.file_index
    assert not store.results and store.stats.n_files == 0
    report = store.fsck(repair=False, spot_check=None)
    assert report.ok and not report.orphans, report.summary()
    # v1's bytes must re-ingest as fresh content, not dedup against a ghost
    res = store.ingest_file(v1_path, "other/m")
    assert not res.file_dedup_hit
    assert store.retrieve_file("other/m", "model.safetensors") == v1
    store.close()


def test_stage_b_failure_releases_file_hash_registration(tmp_path, monkeypatch):
    """Regression (found in review): a stage-B failure BEFORE the pending
    write exists must release the upload's whole-file hash registration —
    otherwise a later identical upload false-dedups against the key's old
    generation (different bytes)."""
    v1_dir = str(tmp_path / "v1" / "org")
    _write_model(os.path.join(v1_dir, "model.safetensors"),
                 np.random.RandomState(61), scale=1.0)
    v1 = open(os.path.join(v1_dir, "model.safetensors"), "rb").read()
    v2_path = str(tmp_path / "v2" / "model.safetensors")
    _write_model(v2_path, np.random.RandomState(62), scale=1.0)
    v2 = open(v2_path, "rb").read()

    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_repo(v1_dir, "org")

    real_plan = ZLLMStore._plan_tensors
    monkeypatch.setattr(ZLLMStore, "_plan_tensors",
                        lambda *a, **k: (_ for _ in ()).throw(
                            OSError("source truncated under ingest")))
    with pytest.raises(OSError):
        store.ingest_file(v2_path, "org")   # failed re-registration, stage B
    monkeypatch.setattr(ZLLMStore, "_plan_tensors", real_plan)

    assert store.retrieve_file("org", "model.safetensors") == v1
    # v2's bytes must ingest FRESH under another key, not dedup to org@old
    res = store.ingest_file(v2_path, "other/m")
    assert not res.file_dedup_hit
    assert store.retrieve_file("other/m", "model.safetensors") == v2
    report = store.fsck(repair=False, spot_check=None)
    assert report.ok, report.summary()
    store.close()


def test_failed_reregistration_write_restores_previous_entry(tmp_path, monkeypatch):
    """Regression (found in review): rolling back a FAILED re-registration
    write must restore the key's previous index record — the old generation
    is still on disk and must stay retrievable, and gc() must not reclaim
    it."""
    rng = np.random.RandomState(41)
    v1_dir = str(tmp_path / "v1" / "org")
    _write_model(os.path.join(v1_dir, "model.safetensors"), rng, scale=1.0)
    v1 = open(os.path.join(v1_dir, "model.safetensors"), "rb").read()
    v2_path = str(tmp_path / "v2" / "model.safetensors")
    _write_model(v2_path, np.random.RandomState(99), scale=1.0)

    store = ZLLMStore(str(tmp_path / "store"), workers=2)
    store.ingest_repo(v1_dir, "org")

    from repro.core.bitx import BitXWriter
    monkeypatch.setattr(BitXWriter, "write",
                        lambda self, path: (_ for _ in ()).throw(
                            OSError(28, "No space left on device")))
    with pytest.raises(OSError):
        store.ingest_file(v2_path, "org")
    monkeypatch.undo()

    # the key still serves the OLD generation, and gc reclaims nothing
    assert store.retrieve_file("org", "model.safetensors") == v1
    assert store.gc()["collected"] == 0
    assert store.retrieve_file("org", "model.safetensors") == v1
    report = store.fsck(repair=False, spot_check=None)
    assert report.ok and not report.orphans, report.summary()
    # whole-file dedup still recognizes the old bytes
    copy_path = str(tmp_path / "copy" / "model.safetensors")
    os.makedirs(os.path.dirname(copy_path), exist_ok=True)
    open(copy_path, "wb").write(v1)
    assert store.ingest_file(copy_path, "mirror").file_dedup_hit
    # and the re-registration succeeds once the disk recovers
    res = store.ingest_file(v2_path, "org")
    assert not res.file_dedup_hit
    assert store.retrieve_file("org", "model.safetensors") == \
        open(v2_path, "rb").read()
    store.close()


# ---------------------------------------------------------------------------
# Base-map cache: one hash pass per base, ever
# ---------------------------------------------------------------------------

def test_base_hashed_exactly_once_for_k_finetunes(tmp_path):
    rng = np.random.RandomState(0)
    n_tensors, K = 6, 4
    base_dir = str(tmp_path / "hub" / "org" / "base")
    base = _write_model(os.path.join(base_dir, "model.safetensors"), rng, n_tensors)

    store = ZLLMStore(str(tmp_path / "store"), workers=2)
    store.ingest_repo(base_dir, "org/base")
    assert store.tensor_dedup.hash_calls == n_tensors  # the ONE base hash pass

    for k in range(K):
        ft_dir = str(tmp_path / "hub" / f"u{k}" / "ft")
        _write_finetune(os.path.join(ft_dir, "model.safetensors"), base, rng)
        store.ingest_file(os.path.join(ft_dir, "model.safetensors"),
                          f"u{k}/ft", declared_base="org/base")

    # K fine-tunes hashed their own tensors only — the base was never re-read
    assert store.tensor_dedup.hash_calls == n_tensors * (1 + K)
    assert store.base_map_stats == {"hits": K, "misses": 0, "primed": 1,
                                    "invalidations": 0}
    assert all(r.n_bitx > 0 for r in store.results[1:])
    store.close()


def test_base_map_invalidated_on_reregistration(tmp_path):
    """Re-ingesting a new standalone file under an existing key must drop the
    cached base map; later fine-tunes delta against the NEW base bytes."""
    rng = np.random.RandomState(1)
    key_id = "orgX/model.safetensors"
    v1_dir = str(tmp_path / "v1" / "orgX")
    v1 = _write_model(os.path.join(v1_dir, "model.safetensors"), rng)
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_repo(v1_dir, "orgX")

    ft1_path = str(tmp_path / "ft1" / "model.safetensors")
    _write_finetune(ft1_path, v1, rng)
    store.ingest_file(ft1_path, "u1/ft1", declared_base=key_id)
    assert store.base_map_stats["hits"] == 1 and store.base_map_stats["misses"] == 0

    # v2: unrelated weights (different scale => large bit distance, so the
    # family matcher keeps it standalone), same shapes, SAME repo/filename key
    v2_dir = str(tmp_path / "v2" / "orgX")
    v2 = _write_model(os.path.join(v2_dir, "model.safetensors"),
                      np.random.RandomState(99), scale=1.0)
    store.ingest_file(os.path.join(v2_dir, "model.safetensors"), "orgX")
    assert store.base_map_stats["invalidations"] >= 1

    ft2_path = str(tmp_path / "ft2" / "model.safetensors")
    ft2 = _write_finetune(ft2_path, v2, rng)
    res = store.ingest_file(ft2_path, "u2/ft2", declared_base=key_id)
    assert res.n_bitx > 0
    # ft2's deltas must reference v2 tensors (small deltas => strong reduction)
    assert store.retrieve_file("u2/ft2", "model.safetensors") == open(ft2_path, "rb").read()
    store.close()


def test_explicit_base_map_invalidation_rebuilds_with_one_pass(tmp_path):
    rng = np.random.RandomState(2)
    base_dir = str(tmp_path / "hub" / "org" / "b")
    base = _write_model(os.path.join(base_dir, "model.safetensors"), rng, n_tensors=5)
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_repo(base_dir, "org/b")
    calls_after_base = store.tensor_dedup.hash_calls

    store.invalidate_base_map()
    assert store.base_map_stats["invalidations"] >= 1
    ft_dir = str(tmp_path / "hub" / "u" / "ft")
    _write_finetune(os.path.join(ft_dir, "model.safetensors"), base, rng)
    store.ingest_file(os.path.join(ft_dir, "model.safetensors"), "u/ft",
                      declared_base="org/b")
    # exactly ONE rebuild pass over the base + the fine-tune's own tensors
    assert store.tensor_dedup.hash_calls == calls_after_base + 5 + 5
    assert store.base_map_stats["misses"] == 1
    store.close()


# ---------------------------------------------------------------------------
# Index persistence (regression: tensor_dedup state used to be dropped)
# ---------------------------------------------------------------------------

def test_index_roundtrip_preserves_tensor_dedup_state(tmp_path):
    rng = np.random.RandomState(3)
    a_dir = str(tmp_path / "hub" / "org" / "a")
    a = _write_model(os.path.join(a_dir, "model.safetensors"), rng, n_tensors=5)
    s1 = ZLLMStore(str(tmp_path / "store"))
    s1.ingest_repo(a_dir, "org/a")
    s1.save_index()
    n_unique_before = s1.tensor_dedup.stats.n_unique
    index_before = dict(s1.tensor_dedup.index)
    assert n_unique_before == 5
    s1.close()

    s2 = ZLLMStore(str(tmp_path / "store"))
    assert s2.load_index()
    # regression: the dedup index + stats survive the round-trip
    assert s2.tensor_dedup.index == index_before
    assert s2.tensor_dedup.stats.n_unique == n_unique_before

    # repo with all of a's tensors plus one new one: dup detection + stats
    # continue across the restart instead of re-storing duplicates
    b = dict(a)
    b["model.extra.weight"] = (np.arange(64) / 64).astype(np.float32)
    b_dir = str(tmp_path / "hub" / "org" / "b")
    os.makedirs(b_dir, exist_ok=True)
    st.save_file(b, os.path.join(b_dir, "model.safetensors"))
    res = s2.ingest_file(os.path.join(b_dir, "model.safetensors"), "org/b")
    assert res.n_dedup == 5 and res.n_tensors == 6
    assert s2.tensor_dedup.stats.n_unique == n_unique_before + 1
    assert s2.retrieve_file("org/b", "model.safetensors") == \
        open(os.path.join(b_dir, "model.safetensors"), "rb").read()
    s2.close()


def test_index_roundtrip_preserves_primed_base_maps(tmp_path):
    """After load_index, fine-tune ingest must NOT re-hash the base (the
    primed map is persisted with the index)."""
    rng = np.random.RandomState(4)
    base_dir = str(tmp_path / "hub" / "org" / "b")
    base = _write_model(os.path.join(base_dir, "model.safetensors"), rng, n_tensors=5)
    s1 = ZLLMStore(str(tmp_path / "store"))
    s1.ingest_repo(base_dir, "org/b")
    s1.save_index()
    s1.close()

    s2 = ZLLMStore(str(tmp_path / "store"))
    assert s2.load_index()
    ft_dir = str(tmp_path / "hub" / "u" / "ft")
    _write_finetune(os.path.join(ft_dir, "model.safetensors"), base, rng)
    res = s2.ingest_file(os.path.join(ft_dir, "model.safetensors"), "u/ft",
                         declared_base="org/b")
    assert res.n_bitx > 0
    assert s2.tensor_dedup.hash_calls == 5        # the fine-tune only
    assert s2.base_map_stats["hits"] == 1 and s2.base_map_stats["misses"] == 0
    s2.close()


def test_retrieval_after_load_index_in_fresh_process(tmp_path, corpus_dir):
    root, manifest = corpus_dir
    store_root = str(tmp_path / "store")
    s1 = ZLLMStore(store_root, workers=2)
    for rid, kind in manifest[:4]:
        s1.ingest_repo(os.path.join(root, rid), rid)
    s1.save_index()
    s1.close()

    rid = manifest[1][0]  # a fine-tune (bitx records exercise dependency resolution)
    orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
    code = (
        "import sys, hashlib\n"
        f"sys.path.insert(0, {SRC_DIR!r})\n"
        "from repro.core.pipeline import ZLLMStore\n"
        f"s = ZLLMStore({store_root!r}, workers=2)\n"
        "assert s.load_index()\n"
        f"data = s.retrieve_file({rid!r}, 'model.safetensors')\n"
        "print(hashlib.sha256(data).hexdigest())\n"
    )
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.strip() == hashlib.sha256(orig).hexdigest()


# ---------------------------------------------------------------------------
# Satellites: streaming FileDedup, codec threads arg, mmap reader
# ---------------------------------------------------------------------------

def test_filededup_streams_in_chunks(tmp_path):
    rng = np.random.RandomState(5)
    p = str(tmp_path / "big.bin")
    blob = rng.bytes(3 * 65536 + 17)  # several chunks + ragged tail
    open(p, "wb").write(blob)
    digest, size = sha256_file(p, chunk_bytes=65536)
    assert size == len(blob)
    assert digest == hashlib.sha256(blob).hexdigest()
    fd = FileDedup()
    d1, new1 = fd.scan_file(p, "a")
    d2, new2 = fd.scan_file(p, "b")
    assert d1 == d2 == digest and new1 and not new2


def test_bitx_codec_threads_arg_not_dropped():
    """Regression: BitXCodec used to accept and silently drop ``threads``."""
    codec = BitXCodec(level=3, threads=2)
    assert codec.threads == 2
    rng = np.random.RandomState(6)
    x = rng.randn(4096).astype(np.float32)
    frames, raw = codec.encode_planes(x)
    out = codec.decode_planes(frames, np.dtype("<f4"), (4096,))
    np.testing.assert_array_equal(out, x)


def test_bitx_codec_shared_across_threads_is_deterministic():
    """One codec, many threads (thread-local contexts): frames must equal
    the single-thread encoding bit for bit."""
    from concurrent.futures import ThreadPoolExecutor
    rng = np.random.RandomState(7)
    tensors = [rng.randn(8192).astype(np.float32) for _ in range(8)]
    codec = BitXCodec(level=3)
    serial = [codec.encode_planes(t) for t in tensors]
    with ThreadPoolExecutor(4) as ex:
        parallel = list(ex.map(codec.encode_planes, tensors))
    for (fs, rs), (fp, rp) in zip(serial, parallel):
        assert rs == rp and fs == fp


def test_bitx_reader_mmap_matches_bytes(tmp_path):
    rng = np.random.RandomState(8)
    base = rng.randn(500).astype(np.float32)
    ft = base + rng.randn(500).astype(np.float32) * 1e-4
    w = BitXWriter(file_metadata={"k": "v"})
    w.add_bitx("t0", "F32", (500,), base, ft, "bh", "sh")
    w.add_zipnn("t1", "F32", (500,), rng.randn(500).astype(np.float32), "sh2")
    path = str(tmp_path / "c.bitx")
    w.write(path)

    r_mm = BitXReader.open(path, use_mmap=True)
    r_by = BitXReader.open(path, use_mmap=False)
    assert r_mm.file_metadata == r_by.file_metadata
    assert [rec.to_json() for rec in r_mm.records] == [rec.to_json() for rec in r_by.records]
    for idx in range(len(r_mm.records)):
        mm_frames = [bytes(f) for f in r_mm.frames_for(idx)]
        by_frames = [bytes(f) for f in r_by.frames_for(idx)]
        assert mm_frames == by_frames
    out = r_mm.decode_tensor(0, lambda h: base, None)
    np.testing.assert_array_equal(out, ft.view(np.uint32))
    r_mm.close()  # frames may still be referenced; close must not raise
    r_by.close()


def test_reingest_same_key_same_content_is_idempotent(tmp_path):
    """Regression (found by probing): re-ingesting identical content under
    its own key must not replace the container record with a self-referencing
    file-dedup record (which sent retrieval into infinite recursion)."""
    rng = np.random.RandomState(9)
    d = str(tmp_path / "hub" / "org" / "m")
    _write_model(os.path.join(d, "model.safetensors"), rng)
    orig = open(os.path.join(d, "model.safetensors"), "rb").read()
    s = ZLLMStore(str(tmp_path / "store"))
    r1 = s.ingest_repo(d, "org/m")
    r2 = s.ingest_repo(d, "org/m")
    assert not r1[0].file_dedup_hit and r2[0].file_dedup_hit
    assert s.file_index["org/m/model.safetensors"]["kind"] == "container"
    assert s.retrieve_file("org/m", "model.safetensors") == orig
    s.close()


def test_retrieval_caches_cut_container_reads(tmp_path, corpus_dir):
    root, manifest = corpus_dir
    s = ZLLMStore(str(tmp_path / "store"), workers=2)
    for rid, kind in manifest:
        s.ingest_repo(os.path.join(root, rid), rid)
    for rid, kind in manifest:
        s.retrieve_file(rid, "model.safetensors", verify=False)
    stats = s.retrieval_cache_stats
    # dependency resolution must hit the tensor LRU (bases resolved once,
    # reused across fine-tunes) and the reader LRU (no reopen per tensor)
    assert stats["tensor_hits"] > 0
    assert stats["reader_hits"] > stats["reader_misses"]
    s.close()
