"""Substrate tests: optimizers, data pipeline, train step semantics,
fault-tolerant trainer, straggler mitigation, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pipeline import ZLLMStore
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticTokens
from repro.models.api import get_model, init_params, make_batch
from repro.optim.optimizers import (AdamW, Adafactor, OptimizerConfig,
                                    clip_by_global_norm, global_norm,
                                    make_optimizer, warmup_cosine)
from repro.train.step import make_train_step
from repro.train.trainer import (FailureInjector, SimulatedFailure, TrainConfig,
                                 Trainer)

# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_step():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=10, b1=0.9, b2=0.99,
                          weight_decay=0.0, min_lr_ratio=1.0)
    opt = AdamW(cfg)
    p = {"w": jnp.array([[1.0, 2.0]], jnp.float32)}
    g = {"w": jnp.array([[0.5, -0.5]], jnp.float32)}
    s = opt.init(p)
    new_p, s = opt.update(g, s, p)
    # by hand: m=0.1*g? no: m=(1-b1)*g=0.05g... mhat=m/(1-b1)=g; vhat=g^2
    # delta = g/(|g|+eps) = sign(g) -> p - lr*sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [[1.0 - 0.1, 2.0 + 0.1]], rtol=1e-4)


def test_adamw_weight_decay_skips_vectors():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.5, min_lr_ratio=1.0)
    opt = AdamW(cfg)
    p = {"norm": jnp.ones((4,)), "w": jnp.ones((2, 2))}
    g = {"norm": jnp.zeros((4,)), "w": jnp.zeros((2, 2))}
    s = opt.init(p)
    new_p, _ = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(new_p["norm"]), np.ones(4))   # no decay
    assert float(new_p["w"][0, 0]) < 1.0                                 # decayed


def test_adafactor_factored_state_shapes():
    cfg = OptimizerConfig(name="adafactor", factored_min_dim=4)
    opt = Adafactor(cfg)
    p = {"big": jnp.ones((3, 8, 16)), "small": jnp.ones((2,))}
    s = opt.init(p)
    assert s["vr"]["big"].shape == (3, 8)
    assert s["vc"]["big"].shape == (3, 16)
    assert s["v"]["small"].shape == (2,)
    g = {"big": jnp.full((3, 8, 16), 0.1), "small": jnp.full((2,), 0.1)}
    new_p, s2 = opt.update(g, s, p)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in new_p.values())


def test_clip_by_global_norm():
    t = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, g = clip_by_global_norm(t, 1.0)
    np.testing.assert_allclose(float(g), np.sqrt(10 * 9 + 10 * 16), rtol=1e-5)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    s = warmup_cosine(cfg)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)
    assert float(s(jnp.int32(55))) < 1.0


# ---------------------------------------------------------------------------
# Grad accumulation semantics
# ---------------------------------------------------------------------------

def test_microbatch_accumulation_equivalence():
    """G=4 fp32-accumulated mean gradients match the full-batch gradients.

    (Comparing post-Adam params would amplify sign noise on near-zero grads:
    Adam's first step is ±lr regardless of magnitude.)"""
    cfg = get_config("qwen2-7b", smoke=True)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    from repro.configs.base import ShapeCell
    batch = make_batch(cfg, ShapeCell("t", "train", 16, 8), key)

    loss_full, g_full = jax.value_and_grad(model.loss)(params, batch)
    G = 4
    mbs = jax.tree.map(lambda x: x.reshape((G, x.shape[0] // G) + x.shape[1:]), batch)
    g_acc = jax.tree.map(lambda p: np.zeros(p.shape, np.float32), params)
    losses = []
    for i in range(G):
        mb = jax.tree.map(lambda x: x[i], mbs)
        l, g = jax.value_and_grad(model.loss)(params, mb)
        losses.append(float(l))
        g_acc = jax.tree.map(lambda a, x: a + np.asarray(x, np.float32) / G, g_acc, g)
    np.testing.assert_allclose(float(loss_full), np.mean(losses), rtol=1e-2)
    for k in g_full:
        a = np.asarray(g_full[k], np.float32)
        b = g_acc[k]
        denom = max(float(np.abs(a).max()), 1e-6)
        assert float(np.abs(a - b).max()) / denom < 0.06, k


def test_bf16_grad_compression_still_learns():
    cfg = get_config("qwen2-7b", smoke=True)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    from repro.configs.base import ShapeCell
    batch = make_batch(cfg, ShapeCell("t", "train", 16, 4), key)
    opt = AdamW(OptimizerConfig(lr=3e-3, warmup_steps=0, weight_decay=0.0))
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, microbatches=2, grad_dtype="bfloat16"))
    first = None
    for _ in range(4):
        params, state, m = step(params, state, batch)
        first = first or float(m["loss"])
    assert float(m["loss"]) < first


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_restart():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    d1 = SyntheticTokens(cfg)
    b5 = d1.batch_at(5)
    d2 = SyntheticTokens(cfg)
    np.testing.assert_array_equal(d2.batch_at(5)["tokens"], b5["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b5["tokens"][:, 1:], b5["labels"][:, :-1])
    # host sharding covers distinct data
    ca = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3, n_hosts=2, host_index=0)
    cb = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3, n_hosts=2, host_index=1)
    assert not np.array_equal(SyntheticTokens(ca).batch_at(0)["tokens"],
                              SyntheticTokens(cb).batch_at(0)["tokens"])


def test_prefetch_iterator():
    it = PrefetchIterator(iter([1, 2, 3]), prefetch=2)
    assert list(it) == [1, 2, 3]


# ---------------------------------------------------------------------------
# Trainer fault tolerance
# ---------------------------------------------------------------------------

def test_trainer_crash_resume_and_store(tmp_path):
    cfg = TrainConfig(arch=get_config("qwen2-7b", smoke=True), seq_len=16,
                      global_batch=4, steps=8, ckpt_every=3,
                      run_dir=str(tmp_path / "run"), async_checkpoint=False)
    store = ZLLMStore(str(tmp_path / "store"))
    t1 = Trainer(cfg, store=store, run_id="r", failure=FailureInjector(fail_at_step=5))
    with pytest.raises(SimulatedFailure):
        t1.run()
    t2 = Trainer(cfg, store=store, run_id="r")
    assert t2.resumed_from == 3                     # latest committed checkpoint
    h = t2.run()
    assert h[-1]["step"] == 8
    # deterministic data: resumed steps see the same batches the crashed run would
    assert t2.ckpt.latest_step() == 8
    # checkpoints chain through zLLM with a declared base
    chained = [r for r in store.results if r.base_source == "declared"]
    assert chained and all(r.n_bitx > 0 for r in chained)


def test_trainer_elastic_restore_smaller_run(tmp_path):
    """Checkpoint written by one trainer restores into a fresh config."""
    arch = get_config("qwen2-7b", smoke=True)
    c1 = TrainConfig(arch=arch, seq_len=16, global_batch=4, steps=4, ckpt_every=2,
                     run_dir=str(tmp_path / "runA"), async_checkpoint=False)
    t1 = Trainer(c1, run_id="a")
    t1.run()
    # new trainer, same run dir, different global batch (elastic data parallel)
    c2 = TrainConfig(arch=arch, seq_len=16, global_batch=8, steps=6, ckpt_every=2,
                     run_dir=str(tmp_path / "runA"), async_checkpoint=False)
    t2 = Trainer(c2, run_id="a")
    assert t2.resumed_from == 4
    h = t2.run()
    assert h[-1]["step"] == 6 and np.isfinite(h[-1]["loss"])


def test_checkpoint_restore_from_compressed_only(tmp_path):
    """keep_plain=False: restore reconstructs from BitX containers."""
    arch = get_config("falcon-mamba-7b", smoke=True)
    store = ZLLMStore(str(tmp_path / "store"))
    cfg = TrainConfig(arch=arch, seq_len=16, global_batch=2, steps=4, ckpt_every=2,
                      run_dir=str(tmp_path / "run"), async_checkpoint=False,
                      keep_plain_ckpt=False)
    t1 = Trainer(cfg, store=store, run_id="m")
    t1.run()
    assert not any(f.endswith(".safetensors") for f in os.listdir(cfg.run_dir))
    t2 = Trainer(cfg, store=store, run_id="m")
    assert t2.resumed_from == 4


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------

def test_speculative_map_reissues_straggler():
    import threading
    import time
    from repro.checkpoint.straggler import speculative_map

    calls = {"n": 0}
    lock = threading.Lock()
    first_stuck = threading.Event()

    def task(x):
        with lock:
            calls["n"] += 1
            mine = calls["n"]
        if x == 1 and mine == 2:        # first attempt of item 1 hangs
            first_stuck.wait(5.0)
            return -1
        return x * 10

    out = speculative_map(task, [0, 1, 2], timeout=0.2, workers=4)
    first_stuck.set()
    assert out == [0, 10, 20]
    assert calls["n"] >= 4              # at least one speculative re-issue


def test_speculative_map_propagates_hard_failure():
    from repro.checkpoint.straggler import speculative_map

    def bad(x):
        raise ValueError("boom")

    with pytest.raises(ValueError):
        speculative_map(bad, [1], timeout=0.05, workers=2, max_attempts=2)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def test_serve_generate_and_batcher():
    from repro.serve.engine import RequestBatcher, ServeEngine
    cfg = get_config("qwen2-7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params)
    prompts = np.array([[1, 2, 3, 4], [9, 8, 7, 6]], np.int32)
    res = eng.generate(prompts, n_new=4)
    assert res.tokens.shape == (2, 8)
    assert res.tokens.dtype == np.int32

    rb = RequestBatcher(eng, batch_size=2, n_new=3)
    r1 = rb.submit([1, 2, 3])
    r2 = rb.submit([4, 5])
    done = rb.run_once()
    assert set(done) == {r1, r2}
    assert rb.result(r1).shape == (3,)


def test_serve_cold_start_from_store(tmp_path):
    from repro.serve.engine import ServeEngine
    arch = get_config("qwen2-7b", smoke=True)
    store = ZLLMStore(str(tmp_path / "store"))
    cfg = TrainConfig(arch=arch, seq_len=16, global_batch=2, steps=2, ckpt_every=2,
                      run_dir=str(tmp_path / "run"), async_checkpoint=False)
    t = Trainer(cfg, store=store, run_id="serve-run")
    t.run()
    eng = ServeEngine.from_store(store, "serve-run", "checkpoint-00000002.safetensors", arch)
    res = eng.generate(np.array([[1, 2, 3]], np.int32), n_new=2)
    assert res.tokens.shape == (1, 5)
    # the served params equal the trained ones bit-for-bit
    for k, v in t.params.items():
        np.testing.assert_array_equal(
            np.asarray(eng.params[k]).view(np.uint16) if np.asarray(v).dtype.name == "bfloat16" else np.asarray(eng.params[k]),
            np.asarray(v).view(np.uint16) if np.asarray(v).dtype.name == "bfloat16" else np.asarray(v))


def test_moe_group_local_dispatch_equivalence():
    """With ample capacity, group-local dispatch (the collective-term fix,
    EXPERIMENTS §Perf) computes the same function as global dispatch."""
    from repro.models.layers import moe_block
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    B, S, d, f, E = 2, 16, 8, 16, 4
    x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, E), jnp.float32)
    wg = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.1
    y1, aux1 = moe_block(x, router, wg, wu, wd, top_k=2, capacity_factor=8.0,
                         n_groups=1)
    y4, aux4 = moe_block(x, router, wg, wu, wd, top_k=2, capacity_factor=8.0,
                         n_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux4), rtol=1e-5)


def test_moe_matches_dense_reference_ample_capacity():
    """Scatter dispatch == per-token dense gating when nothing drops."""
    from repro.models.layers import moe_block
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 5)
    B, S, d, f, E, k = 1, 8, 4, 8, 4, 2
    x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, E), jnp.float32)
    wg = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.1
    got, _ = moe_block(x, router, wg, wu, wd, top_k=k, capacity_factor=16.0)

    # dense reference: loop tokens, apply top-k experts
    probs = np.asarray(jax.nn.softmax(x.reshape(-1, d) @ router, axis=-1))
    want = np.zeros((B * S, d), np.float32)
    for t in range(B * S):
        idx = np.argsort(-probs[t])[:k]
        w = probs[t][idx] / probs[t][idx].sum()
        for e, wi in zip(idx, w):
            h = np.asarray(x.reshape(-1, d))[t]
            g = h @ np.asarray(wg[e])
            u = h @ np.asarray(wu[e])
            silu = g / (1 + np.exp(-g))
            want[t] += wi * ((silu * u) @ np.asarray(wd[e]))
    np.testing.assert_allclose(np.asarray(got).reshape(-1, d), want,
                               rtol=2e-4, atol=2e-4)
