"""Fault-injection + convergence suite for the replicated tier.

Mirrors ``test_crash_recovery.py``: the replication story is *ordering +
idempotence*, not handlers. Spool copies are staged before any enqueue,
containers ship temp-suffix + atomic-rename and are sha256-verified
against the donor, tombstones merge commutatively, and every repair
primitive (adopt / restore / apply_tombstone) is idempotent — so killing
the router at ANY declared fault point leaves a cluster that one
``anti_entropy()`` sweep returns to full convergence with zero
live-tensor loss. This suite kills at each point in
``REPLICATION_FAULT_POINTS``, reopens every root from disk like a
restarted node, and proves exactly that.
"""

import http.client
import os
import struct
import threading
import time
from collections import OrderedDict

import numpy as np
import pytest

from repro.core.lifecycle import make_vid
from repro.core.pipeline import AutoCompactPolicy, ZLLMStore
from repro.formats import safetensors as st
from repro.serve.router import (REPLICATION_FAULT_POINTS, QuorumError,
                                StoreRouter)
from repro.serve.store_server import ServerThread

N_ROOTS = 3
FNAME = "model.safetensors"


def _write_model(path, seed, n_tensors=3, n=1024):
    rng = np.random.RandomState(seed)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tensors = {f"t{i}": (rng.randn(n) * 0.02).astype(np.float32)
               for i in range(n_tensors)}
    st.save_file(tensors, path)
    with open(path, "rb") as f:
        return f.read()


def _corrupt_payload(cpath):
    """Flip bytes in the middle of the frame payload (header left intact)."""
    with open(cpath, "rb") as f:
        blob = bytearray(f.read())
    (hlen,) = struct.unpack("<Q", bytes(blob[8:16]))
    mid = 16 + hlen + (len(blob) - 16 - hlen) // 2
    for i in range(mid, min(mid + 8, len(blob))):
        blob[i] ^= 0xFF
    with open(cpath, "wb") as f:
        f.write(bytes(blob))


def _cluster(root, *, replicas=N_ROOTS, write_quorum=2, load=False):
    stores = OrderedDict()
    for i in range(N_ROOTS):
        s = ZLLMStore(os.path.join(root, f"r{i}"), workers=1)
        if load:
            s.load_index()
        stores[f"r{i}"] = s
    return StoreRouter(stores, replicas=replicas, write_quorum=write_quorum)


def _wait_jobs(router, jobs, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        states = {n: router.roots[n].ingest_job(j) for n, j in jobs.items()}
        if all(s is not None and s["state"] in ("done", "failed")
               for s in states.values()):
            return states
        time.sleep(0.02)
    raise TimeoutError(f"jobs never settled: {states}")


def _drain_workers(router, timeout=60.0):
    """Let every queued job (including async repair jobs) finish."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        pending = [j for s in router.roots.values()
                   for j in s.ingest_jobs(256)
                   if j["state"] in ("queued", "running")]
        if not pending:
            return
        time.sleep(0.02)
    raise TimeoutError("job workers never drained")


def _put(router, tmp, repo_id, seed):
    src = os.path.join(tmp, "up", repo_id.replace("/", "_"), FNAME)
    blob = _write_model(src, seed)
    rep = router.replicated_enqueue(src, repo_id, FNAME)
    _wait_jobs(router, rep["jobs"])
    return blob, rep


def _assert_converged(router, oracle):
    """Convergence = empty replica diffs, clean fsck on every root, and
    every live file byte-identical to the oracle on every up replica."""
    assert router.replica_index_diff() == {}
    for name, store in router.roots.items():
        if not router.is_up(name):
            continue
        rep = store.fsck(repair=False, spot_check=None)
        assert rep.ok, (name, rep.dangling, rep.corrupt)
    for repo_id, blob in oracle.items():
        for name in router.replica_roots(repo_id):
            if not router.is_up(name):
                continue
            assert router.roots[name].retrieve_file(repo_id, FNAME) == blob, \
                f"live tensor data lost for {repo_id} on {name}"


class _Kill(BaseException):
    """BaseException so no except-Exception handler on the way out can
    soften the simulated crash."""


# ---------------------------------------------------------------------------
# happy path: quorum writes fan out bit-identically
# ---------------------------------------------------------------------------

def test_replicated_write_is_byte_identical_everywhere(tmp_path):
    router = _cluster(str(tmp_path))
    try:
        blob, rep = _put(router, str(tmp_path), "org/a", seed=1)
        assert sorted(rep["jobs"]) == ["r0", "r1", "r2"]
        for name in router.roots:
            assert router.roots[name].retrieve_file("org/a", FNAME) == blob
        # container-level identity, not just decoded-bytes identity
        key = f"org/a/{FNAME}"
        gen = router.roots["r0"].file_index[key]["gen"]
        digests = {s.container_digest(key, gen)
                   for s in router.roots.values()}
        assert len(digests) == 1
        _assert_converged(router, {"org/a": blob})
    finally:
        router.close()


def test_write_quorum_respected_and_503_below_it(tmp_path):
    router = _cluster(str(tmp_path))
    try:
        victim = router.replica_roots("org/q")[0]
        router.set_root_down(victim)
        blob, rep = _put(router, str(tmp_path), "org/q", seed=2)
        assert victim in rep["failed"] and len(rep["jobs"]) == 2
        ok, _ = router.await_quorum(rep["jobs"])
        assert ok
        # two roots down -> W=2 unreachable -> QuorumError
        survivors = [n for n in router.roots if n != victim]
        router.set_root_down(survivors[0])
        src = os.path.join(str(tmp_path), "up2", FNAME)
        _write_model(src, 3)
        with pytest.raises(QuorumError):
            router.replicated_enqueue(src, "org/q2", FNAME)
    finally:
        router.close()


def test_restarted_root_converges_via_anti_entropy(tmp_path):
    """Acceptance demo: write at W=2 with one root down, 'restart' the
    root (reopen all stores from disk), one sweep converges it."""
    tmp = str(tmp_path)
    router = _cluster(tmp)
    victim = router.replica_roots("org/m")[0]
    router.set_root_down(victim)
    blob, _ = _put(router, tmp, "org/m", seed=4)
    _drain_workers(router)
    router.close()

    router = _cluster(tmp, load=True)  # every node restarts
    try:
        assert f"org/m/{FNAME}" not in router.roots[victim].file_index
        rep = router.anti_entropy()
        assert rep["shipped_versions"] >= 1 and not rep["errors"]
        _assert_converged(router, {"org/m": blob})
        assert router.roots[victim].retrieve_file("org/m", FNAME) == blob
    finally:
        router.close()


# ---------------------------------------------------------------------------
# read failover
# ---------------------------------------------------------------------------

def test_read_candidates_exclude_down_roots_and_recover(tmp_path):
    router = _cluster(str(tmp_path))
    try:
        blob, _ = _put(router, str(tmp_path), "org/r", seed=5)
        cands = router.read_candidates("org/r", FNAME)
        assert len(cands) == N_ROOTS
        router.set_root_down(cands[0])
        after = router.read_candidates("org/r", FNAME)
        assert cands[0] not in after and len(after) == N_ROOTS - 1
        assert router.roots[after[0]].retrieve_file("org/r", FNAME) == blob
        # suspect backoff: repeated failures push a root to the back
        for _ in range(3):
            router.note_failure(after[0])
        assert router.health()[after[0]]["state"] == "suspect"
        assert router.read_candidates("org/r", FNAME)[-1] == after[0]
        router.note_success(after[0])
        assert router.health()[after[0]]["state"] == "up"
        router.set_root_down(cands[0], down=False)
        assert router.health()[cands[0]]["state"] == "up"
    finally:
        router.close()


# ---------------------------------------------------------------------------
# kill at every declared fault point; reopen; one sweep heals
# ---------------------------------------------------------------------------

def _arm(router, point, fired):
    def hook(p):
        if p == point:
            fired.append(p)
            raise _Kill(p)
    router.fault_hook = hook


def _reopen_and_heal(tmp, oracle):
    router = _cluster(tmp, load=True)
    try:
        router.anti_entropy()
        _assert_converged(router, oracle)
    finally:
        router.close()


@pytest.mark.parametrize("point", [p for p in REPLICATION_FAULT_POINTS
                                   if p.startswith("put.")])
def test_put_killed_at_fault_point_then_heals(point, tmp_path):
    tmp = str(tmp_path)
    router = _cluster(tmp)
    blob0, _ = _put(router, tmp, "org/base", seed=10)  # pre-existing state
    src = os.path.join(tmp, "up", FNAME)
    blob = _write_model(src, 11)
    fired = []
    _arm(router, point, fired)
    with pytest.raises(_Kill):
        router.replicated_enqueue(src, "org/x", FNAME)
    assert fired == [point]
    router.fault_hook = None
    _drain_workers(router)  # jobs already accepted before the kill finish
    router.close()

    # reopen every node; the sweep must either complete the write on every
    # replica (some root accepted it) or leave a still-converged cluster
    router = _cluster(tmp, load=True)
    try:
        router.anti_entropy()
        holders = [n for n in router.roots
                   if f"org/x/{FNAME}" in router.roots[n].file_index]
        assert holders in ([], sorted(router.roots)), \
            f"partial replication survived the sweep: {holders}"
        oracle = {"org/base": blob0}
        if holders:
            oracle["org/x"] = blob
        _assert_converged(router, oracle)
    finally:
        router.close()


def test_anti_entropy_killed_mid_copy_then_heals(tmp_path):
    tmp = str(tmp_path)
    router = _cluster(tmp)
    victim = router.replica_roots("org/ae")[0]
    router.set_root_down(victim)
    blob, _ = _put(router, tmp, "org/ae", seed=12)
    _drain_workers(router)
    router.set_root_down(victim, down=False)
    fired = []
    _arm(router, "anti_entropy.mid_copy", fired)
    with pytest.raises(_Kill):
        router.anti_entropy()
    assert fired == ["anti_entropy.mid_copy"]
    router.close()
    _reopen_and_heal(tmp, {"org/ae": blob})


def test_restore_killed_mid_copy_then_heals(tmp_path):
    tmp = str(tmp_path)
    router = _cluster(tmp)
    blob, _ = _put(router, tmp, "org/qr", seed=13)
    key = f"org/qr/{FNAME}"
    victim = router.replica_roots("org/qr")[0]
    store = router.roots[victim]
    gen = store.file_index[key]["gen"]
    _corrupt_payload(store.lifecycle.version_path(key, gen))
    assert store.fsck(repair=True, spot_check=None).quarantined
    fired = []
    _arm(router, "restore.mid_copy", fired)
    with pytest.raises(_Kill):
        router.anti_entropy()
    assert fired == ["restore.mid_copy"]
    router.close()
    _reopen_and_heal(tmp, {"org/qr": blob})


# ---------------------------------------------------------------------------
# end-to-end heal: corrupt -> failover -> quarantine -> restore -> clean
# ---------------------------------------------------------------------------

def test_corruption_heals_end_to_end_with_bit_identity(tmp_path):
    tmp = str(tmp_path)
    router = _cluster(tmp)
    try:
        blob, _ = _put(router, tmp, "org/heal", seed=20)
        key = f"org/heal/{FNAME}"
        victim = router.read_candidates("org/heal", FNAME)[0]
        store = router.roots[victim]
        gen = store.file_index[key]["gen"]
        healthy_digest = router.roots[
            [n for n in router.roots if n != victim][0]
        ].container_digest(key, gen)
        _corrupt_payload(store.lifecycle.version_path(key, gen))

        # fsck quarantines the corrupt replica copy
        rep = store.fsck(repair=True, spot_check=None)
        assert make_vid(key, gen) in rep.quarantined
        with pytest.raises(RuntimeError, match="quarantined"):
            store.retrieve_file("org/heal", FNAME)

        # routed reads keep serving byte-identical data from the others
        for name in router.read_candidates("org/heal", FNAME):
            if name == victim:
                continue
            assert router.roots[name].retrieve_file("org/heal", FNAME) == blob

        # anti-entropy re-ships the healthy copy and swaps it back in
        ae = router.anti_entropy()
        assert ae["restored"] == 1 and not ae["errors"]
        assert store.retrieve_file("org/heal", FNAME) == blob
        assert store.container_digest(key, gen) == healthy_digest
        _assert_converged(router, {"org/heal": blob})
    finally:
        router.close()


# ---------------------------------------------------------------------------
# tombstones: deletes propagate, nothing resurrects, re-uploads supersede
# ---------------------------------------------------------------------------

def test_delete_tombstones_survive_restart_and_block_resurrection(tmp_path):
    tmp = str(tmp_path)
    router = _cluster(tmp)
    blob, _ = _put(router, tmp, "org/del", seed=30)
    victim = router.replica_roots("org/del")[0]
    router.set_root_down(victim)  # this replica misses the delete
    out = router.delete("org/del", FNAME)
    assert out["deleted"] == 1 and victim in out["failed"]
    router.close()

    router = _cluster(tmp, load=True)
    try:
        # the down replica still holds the record — without tombstones the
        # sweep would re-ship it to everyone (resurrection)
        assert f"org/del/{FNAME}" in router.roots[victim].file_index
        rep = router.anti_entropy()
        assert rep["tombstones_applied"] >= 1
        for name, store in router.roots.items():
            assert f"org/del/{FNAME}" not in store.file_index, \
                f"deleted file resurrected on {name}"
        assert router.replica_index_diff() == {}
    finally:
        router.close()


def test_reupload_after_delete_supersedes_stale_tombstone(tmp_path):
    tmp = str(tmp_path)
    router = _cluster(tmp)
    try:
        _put(router, tmp, "org/re", seed=31)
        victim = router.replica_roots("org/re")[0]
        router.set_root_down(victim)  # marker will linger here
        router.delete("org/re", FNAME)
        blob2, _ = _put(router, tmp, "org/re", seed=32)  # legit re-upload
        router.set_root_down(victim, down=False)
        router.anti_entropy()
        for name, store in router.roots.items():
            assert store.retrieve_file("org/re", FNAME) == blob2, \
                f"stale tombstone wiped the re-upload on {name}"
        assert router.replica_index_diff() == {}
    finally:
        router.close()


# ---------------------------------------------------------------------------
# regression: fsck quarantine must persist its index mutations
# ---------------------------------------------------------------------------

def test_fsck_quarantine_persists_index_and_scrubbed_pins(tmp_path):
    """fsck(repair=True) scrubs tensor pins and re-paths the quarantined
    record in memory — but a restarted process reloads the on-disk index.
    The repair must persist, or the reopened store still pins the
    quarantined generation at its vanished path."""
    root = str(tmp_path / "s")
    store = ZLLMStore(root, workers=0)
    src = os.path.join(str(tmp_path), "hub", FNAME)
    _write_model(src, 40)
    store.ingest_file(src, "org/p")
    store.save_index()
    key = f"org/p/{FNAME}"
    gen = store.file_index[key]["gen"]
    _corrupt_payload(store.lifecycle.version_path(key, gen))
    assert store.fsck(repair=True, spot_check=None).quarantined
    store.close()

    with ZLLMStore(root, workers=0) as s2:
        assert s2.load_index()
        qvid = make_vid(key, gen)
        v = s2.lifecycle.versions[qvid]
        assert v.quarantined, "quarantine flag was not persisted"
        assert not any(make_vid(k, g) == qvid
                       for (k, g, _i) in s2.tensor_locations.values()), \
            "reopened index still pins the quarantined generation"
        rep = s2.fsck(repair=False, spot_check=None)
        assert not rep.corrupt and not rep.orphans


# ---------------------------------------------------------------------------
# automatic compaction trigger
# ---------------------------------------------------------------------------

def test_auto_compact_watermark_math():
    pol = AutoCompactPolicy(min_superseded_bytes=100, superseded_ratio=0.25)
    assert not pol.should_compact(99, 0, 1)          # below absolute floor
    assert pol.should_compact(100, 0, 1)             # floor met, live=0
    assert not pol.should_compact(100, 1000, 1)      # 10% < 25% of live
    assert pol.should_compact(250, 1000, 1)          # exactly at the ratio
    assert pol.should_compact(251, 1000, 1)
    # sweep-counter backstop fires regardless of byte watermarks
    pol = AutoCompactPolicy(min_superseded_bytes=1 << 60, every_n_gc=3)
    assert not pol.should_compact(0, 0, 2)
    assert pol.should_compact(0, 0, 3)
    # disabled backstop never fires on the counter alone
    pol = AutoCompactPolicy(min_superseded_bytes=1 << 60, every_n_gc=None)
    assert not pol.should_compact(0, 0, 10 ** 6)


def test_gc_fires_auto_compact_at_watermark(tmp_path):
    store = ZLLMStore(str(tmp_path / "s"), workers=0,
                      auto_compact=AutoCompactPolicy(min_superseded_bytes=1,
                                                     superseded_ratio=0.01))
    rng = np.random.RandomState(50)
    cur = {f"t{i}": rng.randn(1024).astype(np.float32) for i in range(4)}
    # one path per generation: a source file registered as a BitX base
    # must not be mutated in place (its tensor map is primed at ingest)
    p = os.path.join(str(tmp_path), "hub", "g0", FNAME)
    os.makedirs(os.path.dirname(p), exist_ok=True)
    st.save_file(cur, p)
    store.ingest_file(p, "org/c")
    for r in range(3):  # superseded-but-pinned generations for compact
        cur[f"t{r}"] = rng.randn(1024).astype(np.float32)
        p = os.path.join(str(tmp_path), "hub", f"g{r + 1}", FNAME)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        st.save_file(dict(cur), p)
        assert store.ingest_file(p, "org/c").n_dedup > 0
    before = store._compactable_superseded_bytes()
    assert before > 0
    store.gc()
    assert store.stats.auto_compact_runs == 1
    assert store._compactable_superseded_bytes() < before  # compact ran
    with open(p, "rb") as f:
        blob = f.read()
    assert store.retrieve_file("org/c", FNAME) == blob
    # hysteresis: a converged compact leaves a residual floor (bitx bases,
    # cost-gated moves); without new churn further sweeps must not re-fire
    store.gc()
    store.gc()
    assert store.stats.auto_compact_runs == 1
    store.close()


# ---------------------------------------------------------------------------
# Bugfix regression: the repair-pending backlog is capped and TTL-pruned
# ---------------------------------------------------------------------------

def test_repair_pending_backlog_caps_and_expires(tmp_path):
    """Regression (failing-first): the backlog of repos awaiting a sweep
    used to be a bare set — a flapping replica could grow it without
    bound, and an entry whose sweep never came lived forever. It is now
    an insertion-ordered, re-stampable map with a hard cap (oldest
    evicted first) and a TTL prune on read."""
    router = _cluster(str(tmp_path))
    try:
        router.REPAIR_PENDING_MAX = 4
        for i in range(10):
            router._note_repair_pending(f"org/bl{i}")
        assert len(router._repair_pending) == 4
        assert router._pending_repairs() == {f"org/bl{i}" for i in range(6, 10)}
        # re-stamping refreshes an entry instead of duplicating it; the
        # next insert evicts the oldest UN-refreshed repo
        router._note_repair_pending("org/bl6")
        router._note_repair_pending("org/new")
        pending = router._pending_repairs()
        assert "org/bl6" in pending and "org/new" in pending
        assert "org/bl7" not in pending and len(router._repair_pending) == 4
        # TTL prune: an expired backlog drains to empty on read
        router.REPAIR_PENDING_TTL_S = 0.05
        time.sleep(0.06)
        assert router._pending_repairs() == set()
        assert not router._repair_pending
        # a sweep consumes what it swept, even for a vanished repo
        router.REPAIR_PENDING_TTL_S = 3600.0
        router._note_repair_pending("org/gone")
        router.anti_entropy()
        assert "org/gone" not in router._repair_pending
    finally:
        router.close()


# ---------------------------------------------------------------------------
# Bugfix regression: probe thundering herd after the backoff expires
# ---------------------------------------------------------------------------

def test_probe_after_backoff_is_claimed_single_flight(tmp_path):
    """Regression (failing-first): once a suspect root's backoff deadline
    passed, `_probe_ok` used to return True for EVERY concurrent caller,
    so all waiting reads led with the just-recovered root at once. The
    probe is now claimed: exactly one concurrent read targets it, the
    rest keep it as a last resort until the claimant's request resolves."""
    router = _cluster(str(tmp_path))
    try:
        repo = "org/herd"
        group = router.replica_roots(repo)
        victim = group[0]
        router.note_failure(victim)  # suspect, 0.5 s backoff
        assert router.read_candidates(repo, FNAME)[-1] == victim
        time.sleep(0.6)              # the probe deadline passes

        n = 8
        barrier = threading.Barrier(n)
        leads, lock = [], threading.Lock()

        def read():
            barrier.wait()
            cands = router.read_candidates(repo, FNAME)
            with lock:
                leads.append(cands[0])

        threads = [threading.Thread(target=read) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert leads.count(victim) == 1, \
            f"{leads.count(victim)}/{n} concurrent reads probed the " \
            f"recovering root (thundering herd)"
        # the claimant's outcome resolves the probe either way
        router.note_success(victim)
        assert router.read_candidates(repo, FNAME)[0] == victim
        router.note_failure(victim)  # failed probe: suspect again, longer
        assert router.read_candidates(repo, FNAME)[-1] == victim
    finally:
        router.close()


# ---------------------------------------------------------------------------
# read-repair: a failover read off a divergent replica converges the group
# ---------------------------------------------------------------------------

class _Client:
    """Minimal HTTP client for the read-repair tests."""

    def __init__(self, srv):
        self.conn = http.client.HTTPConnection(srv.host, srv.port, timeout=60)

    def get(self, path, headers=None):
        self.conn.request("GET", path, headers=headers or {})
        r = self.conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()

    def close(self):
        self.conn.close()


def _diverge(router, tmp, repo_id, seed):
    """Down the repo's first replica, advance the others one generation,
    bring it back: the group now disagrees on (key, gen)."""
    victim = router.replica_roots(repo_id)[0]
    router.set_root_down(victim)
    blob, _ = _put(router, tmp, repo_id, seed)
    _drain_workers(router)  # incl. the automatic straggler repair, which
    # cannot reach the down root and leaves the divergence in place
    router.set_root_down(victim, down=False)
    return victim, blob


def test_failover_read_schedules_scoped_read_repair(tmp_path):
    """Tentpole acceptance: a read off a divergent group serves the
    strongest validator, schedules an asynchronous per-repo repair that
    converges the group — and does NOT run a full sweep (an unrelated
    divergent repo stays divergent until its own repair)."""
    tmp = str(tmp_path)
    router = _cluster(tmp)
    router.READ_REPAIR_COOLDOWN_S = 0.0
    try:
        blob1, _ = _put(router, tmp, "org/rr", seed=60)
        _put(router, tmp, "org/other", seed=61)
        _drain_workers(router)
        victim, blob2 = _diverge(router, tmp, "org/rr", seed=62)
        _, blob_o2 = _diverge(router, tmp, "org/other", seed=63)
        assert router.replica_index_diff(repos=["org/rr"]) != {}
        key = f"org/rr/{FNAME}"

        with ServerThread(router, max_concurrency=2) as srv:
            c = _Client(srv)
            try:
                newest = max(r.file_index[key]["gen"]
                             for r in router.roots.values())
                status, h, body = c.get(f"/repo/org/rr/file/{FNAME}")
                # the plan orders strongest-record-first: the stale
                # replica never wins, a failover read never serves a
                # weaker validator
                assert status == 200 and body == blob2
                assert h["etag"] == f'"{key}@g{newest}"'
                # the stale generation's validator misses; the current
                # one revalidates — even across failover ordering
                s2, _, b2 = c.get(f"/repo/org/rr/file/{FNAME}",
                                  {"If-None-Match": f'"{key}@g{newest - 1}"'})
                assert s2 == 200 and b2 == blob2
                assert c.get(f"/repo/org/rr/file/{FNAME}",
                             {"If-None-Match":
                              f'"{key}@g{newest}"'})[0] == 304
                deadline = time.monotonic() + 30
                while router.replica_index_diff(repos=["org/rr"]) \
                        and time.monotonic() < deadline:
                    time.sleep(0.05)
            finally:
                c.close()

        assert router.read_repairs >= 1
        assert router.replica_index_diff(repos=["org/rr"]) == {}, \
            "read-repair never converged the group"
        assert router.roots[victim].retrieve_file("org/rr", FNAME) == blob2
        # scoped, not a sweep: the other divergent repo was left alone
        assert router.replica_index_diff(repos=["org/other"]) != {}
        # end state: one explicit sweep, full convergence, byte oracle
        router.anti_entropy()
        _drain_workers(router)
        _assert_converged(router, {"org/rr": blob2, "org/other": blob_o2})
    finally:
        router.close()


def test_read_repair_killed_mid_copy_retriggers_and_heals(tmp_path):
    """Fault-injection harness over the read-repair path: the first
    repair job dies at `anti_entropy.mid_copy` (error recorded, no
    convergence); the next failover read schedules a fresh repair that
    heals the group. Idempotent adoption makes the retry safe."""
    tmp = str(tmp_path)
    router = _cluster(tmp)
    router.READ_REPAIR_COOLDOWN_S = 0.0
    try:
        _put(router, tmp, "org/rk", seed=70)
        _drain_workers(router)
        victim, blob2 = _diverge(router, tmp, "org/rk", seed=71)
        fired = []

        def hook(point):
            if point == "anti_entropy.mid_copy" and not fired:
                fired.append(point)
                raise RuntimeError(f"injected fault: {point}")

        router.fault_hook = hook
        with ServerThread(router, max_concurrency=2) as srv:
            c = _Client(srv)
            try:
                status, _, body = c.get(f"/repo/org/rk/file/{FNAME}")
                assert status == 200 and body == blob2
                _drain_workers(router)
                assert fired == ["anti_entropy.mid_copy"]
                # poisoned repair: the group is still divergent
                assert router.replica_index_diff(repos=["org/rk"]) != {}
                # the next read re-triggers; this repair completes
                status, _, body = c.get(f"/repo/org/rk/file/{FNAME}")
                assert status == 200 and body == blob2
                deadline = time.monotonic() + 30
                while router.replica_index_diff(repos=["org/rk"]) \
                        and time.monotonic() < deadline:
                    time.sleep(0.05)
            finally:
                c.close()
        router.fault_hook = None
        assert router.read_repairs >= 2
        assert router.replica_index_diff(repos=["org/rk"]) == {}
        _drain_workers(router)
        _assert_converged(router, {"org/rk": blob2})
    finally:
        router.close()


def test_read_repair_is_deduped_and_cooled_down(tmp_path):
    """One in-flight repair per repo, plus a completion cooldown: a
    persistently divergent group must not enqueue one job per read."""
    tmp = str(tmp_path)
    router = _cluster(tmp)
    try:
        _put(router, tmp, "org/cd", seed=80)
        _drain_workers(router)
        victim = router.replica_roots("org/cd")[0]
        router.set_root_down(victim)  # keep one root down: repair cannot
        # converge the group, so every read sees the same divergence
        blob2, _ = _put(router, tmp, "org/cd", seed=81)
        _drain_workers(router)
        before = router.read_repairs
        first = router.schedule_read_repair("org/cd")
        assert first is not None
        # in-flight dedupe: an immediate reschedule is dropped
        assert router.schedule_read_repair("org/cd") is None
        _drain_workers(router)
        # cooldown (default 5 s): a repair that JUST finished is not
        # rescheduled on the next read either
        assert router.schedule_read_repair("org/cd") is None
        assert router.read_repairs == before + 1
        # zero cooldown (test override): reschedules immediately
        router.READ_REPAIR_COOLDOWN_S = 0.0
        assert router.schedule_read_repair("org/cd") is not None
        _drain_workers(router)
    finally:
        router.close()
