"""Distribution tests: sharding rules, HLO cost model, and multi-device
semantics (run in subprocesses with forced host device counts, since device
count is locked at first jax init)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(script: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_rules_resolution():
    from repro.sharding.rules import ShardingRules, logical_to_spec
    r = ShardingRules(batch=("pod", "data"), fsdp=("data",))
    assert logical_to_spec(("batch", None, "tp"), r) == P(("pod", "data"), None, "model")
    assert logical_to_spec((None,), r) == P(None)


def test_safe_spec_drops_nondivisible():
    out = run_sub("""
        import jax
        from repro.launch.mesh import make_local_mesh
        from repro.sharding.rules import ShardingRules, safe_spec
        mesh = make_local_mesh(4, 2)
        rules = ShardingRules.for_mesh(mesh)
        s1 = safe_spec(mesh, ("batch", "tp"), rules, (8, 6))   # 6 % 2 == 0 -> keep
        s2 = safe_spec(mesh, ("batch", "tp"), rules, (1, 7))   # drop both
        print(s1)
        print(s2)
    """, devices=8)
    lines = out.strip().splitlines()
    assert "'data'" in lines[0] and "'model'" in lines[0]
    assert lines[1] == "PartitionSpec(None, None)"


def test_param_spec_shardings_cover_all_archs():
    from repro.configs import ARCH_IDS, get_config
    from repro.models.api import get_model
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for name, spec in get_model(cfg).param_templates().items():
            assert len(spec.axes) == len(spec.shape), (arch, name)
            if spec.stacked:
                assert spec.axes[0] is None, (arch, name)  # layer dim unsharded


# ---------------------------------------------------------------------------
# HLO cost model
# ---------------------------------------------------------------------------

def test_hlo_cost_scan_tripcount():
    from repro.launch.hlo_cost import analyze_module

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=5)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    cost = analyze_module(c.as_text())
    want_dots = 5 * 2 * 64 * 64 * 64
    assert 0.95 * want_dots <= cost.dot_flops <= 1.05 * want_dots
    assert cost.flops >= cost.dot_flops
    assert cost.unknown_loops == 0


def test_hlo_cost_counts_looped_collectives():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.launch.hlo_cost import analyze_module
        mesh = make_local_mesh(1, 4)
        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            return jax.lax.scan(body, x, None, length=6)[0]
        xs = NamedSharding(mesh, P(None, "model"))
        ws = NamedSharding(mesh, P("model", None))
        c = jax.jit(f, in_shardings=(xs, ws), out_shardings=xs).lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        cost = analyze_module(c.as_text())
        print(int(sum(cost.coll_count.values())))
    """, devices=4)
    assert int(out.strip()) == 6   # one all-reduce per scan iteration


# ---------------------------------------------------------------------------
# Multi-device semantics
# ---------------------------------------------------------------------------

def test_flash_decode_matches_local_attention():
    """decode_attention_sp over a sequence-sharded cache == dense reference."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_local_mesh
        from repro.models import layers as L
        mesh = make_local_mesh(2, 4)
        B, H, Kv, D, S = 4, 8, 2, 16, 64
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (B, H, D), jnp.float32)
        kc = jax.random.normal(k2, (B, S, Kv, D), jnp.float32)
        vc = jax.random.normal(k3, (B, S, Kv, D), jnp.float32)
        t = jnp.int32(37)

        def sp(q, kc, vc):
            return L.decode_attention_sp(q, kc, vc, t, mesh=mesh, sp_axis="model",
                                         batch_axes=("data",))
        got = jax.jit(sp)(q, kc, vc)
        kH = L.repeat_kv(kc, H)
        vH = L.repeat_kv(vc, H)
        want = L.attention(q[:, None], kH, vH, causal=True, q_offset=t - 1)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
        print("OK")
    """, devices=8)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """Same seed, same batch: a (2,2)-mesh train step equals 1-device math."""
    script = """
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.models.api import get_model, init_params, make_batch, param_shardings
        from repro.optim.optimizers import OptimizerConfig, AdamW
        from repro.sharding.rules import ShardingRules, spec_tree_shardings
        from repro.train.step import make_train_step
        from repro.launch.mesh import make_local_mesh
        MESH = %s
        cfg = get_config("qwen2-7b", smoke=True)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        batch = make_batch(cfg, ShapeCell("t", "train", 16, 4, microbatches=2), key)
        opt = AdamW(OptimizerConfig(lr=1e-2, warmup_steps=0))
        if MESH:
            mesh = make_local_mesh(*MESH)
            rules = ShardingRules.for_mesh(mesh)
            model = get_model(cfg, mesh, rules)
            psh = param_shardings(cfg, mesh, rules)
            osh = spec_tree_shardings(opt.state_templates(model.param_templates()), mesh, rules)
            step = jax.jit(make_train_step(model, opt, microbatches=2),
                           in_shardings=(psh, osh, None), out_shardings=(psh, osh, None))
            params = {k: jax.device_put(v, psh[k]) for k, v in params.items()}
        else:
            model = get_model(cfg)
            step = jax.jit(make_train_step(model, opt, microbatches=2))
        state = opt.init(params)
        p, s, m = step(params, state, batch)
        print(json.dumps({"loss": float(m["loss"]), "gn": float(m["grad_norm"]),
                          "w0": float(jnp.sum(jnp.abs(p["embed"].astype(jnp.float32))))}))
    """
    single = json.loads(run_sub(script % "None", devices=4).strip().splitlines()[-1])
    sharded = json.loads(run_sub(script % "(2, 2)", devices=4).strip().splitlines()[-1])
    assert abs(single["loss"] - sharded["loss"]) < 1e-2
    assert abs(single["gn"] - sharded["gn"]) / single["gn"] < 5e-2
    assert abs(single["w0"] - sharded["w0"]) / single["w0"] < 1e-2


def test_mini_multipod_lowering():
    """A tiny multi-pod mesh (2,2,2) lowers and runs a real train step."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.models.api import get_model, init_params, make_batch, param_shardings
        from repro.optim.optimizers import OptimizerConfig, AdamW
        from repro.sharding.rules import ShardingRules, spec_tree_shardings
        from repro.train.step import make_train_step
        from repro.launch.mesh import make_local_mesh
        cfg = get_config("mixtral-8x7b", smoke=True)
        mesh = make_local_mesh(2, 2, pod=2)
        rules = ShardingRules.for_mesh(mesh, fsdp_over_pod=True)
        model = get_model(cfg, mesh, rules)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        batch = make_batch(cfg, ShapeCell("t", "train", 16, 8, microbatches=2), key)
        opt = AdamW(OptimizerConfig(lr=1e-3, warmup_steps=0))
        psh = param_shardings(cfg, mesh, rules)
        osh = spec_tree_shardings(opt.state_templates(model.param_templates()), mesh, rules)
        step = jax.jit(make_train_step(model, opt, microbatches=2),
                       in_shardings=(psh, osh, None), out_shardings=(psh, osh, None))
        params = {k: jax.device_put(v, psh[k]) for k, v in params.items()}
        state = opt.init(params)
        p, s, m = step(params, state, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK", float(m["loss"]))
    """, devices=8)
    assert "OK" in out
