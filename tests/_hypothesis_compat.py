"""`hypothesis` when installed, else a tiny deterministic fallback.

This container ships without the `hypothesis` wheel; rather than skip the
property tests entirely, the fallback drives the same test bodies with a
fixed-seed sampler (a handful of examples per test — far weaker than real
hypothesis shrinking/coverage, but it keeps the lossless-roundtrip
properties exercised in CI). Only the strategy subset this repo uses is
implemented: ``integers``, ``floats`` (width=32, NaN/Inf), ``lists``.

Usage in tests (drop-in for the hypothesis import):

    from _hypothesis_compat import given, settings, strategies as stt
"""

from __future__ import annotations

try:  # pragma: no cover - depends on container contents
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import struct

    HAVE_HYPOTHESIS = False
    _N_EXAMPLES = 8

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rng -> value

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(width=64, allow_nan=False, allow_infinity=False, **_kw):
            specials = [0.0, -0.0]
            if allow_nan:
                specials.append(float("nan"))
            if allow_infinity:
                specials += [float("inf"), float("-inf")]

            def sample(r):
                if specials and r.random() < 0.15:
                    return r.choice(specials)
                # random bit pattern: covers subnormals/odd exponents too
                if width == 32:
                    return struct.unpack("<f", r.getrandbits(32).to_bytes(4, "little"))[0]
                return struct.unpack("<d", r.getrandbits(64).to_bytes(8, "little"))[0]

            def safe(r):
                v = sample(r)
                if not allow_nan and v != v:
                    return 0.0
                if not allow_infinity and v in (float("inf"), float("-inf")):
                    return 0.0
                return v
            return _Strategy(safe)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(r):
                n = r.randint(min_size, max_size)
                return [elements.sample(r) for _ in range(n)]
            return _Strategy(sample)

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    def given(*strats, **kw_strats):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake the wrapped test's
            # drawn parameters for fixtures (so no functools.wraps, which
            # exposes the original signature via __wrapped__)
            def wrapper():
                rng = random.Random(0xC0FFEE)
                for _ in range(_N_EXAMPLES):
                    drawn = [s.sample(rng) for s in strats]
                    drawn_kw = {k: s.sample(rng) for k, s in kw_strats.items()}
                    fn(*drawn, **drawn_kw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
