"""Cross-process replication chaos suite: three REAL peers over the wire.

Where ``test_replication.py`` proves ordering + idempotence against
in-process roots, this suite runs the same convergence story over the
peer HTTP protocol: a coordinator whose replica group mixes one local
root (``rA``) with two :class:`~repro.serve.peer.PeerStore` mounts
(``pB``/``pC``), each backed by a real :class:`ServerThread` process
boundary and fronted by a :class:`~benchmarks.chaos.ChaosProxy` TCP
forwarder. The proxy fails the NETWORK — drop, blackhole, delay,
truncate-mid-body — without touching either process, so the suite can
partition peers, kill transfers mid-body, and heal, then prove one
sweep (or one targeted hint drain) returns every replica to
byte-identical convergence with zero live-tensor loss and zero
``.part`` debris.
"""

import os
import shutil
import tempfile
import time
from collections import OrderedDict

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as stt

from benchmarks.chaos import ChaosProxy
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st
from repro.serve.peer import PeerStore
from repro.serve.router import StoreRouter
from repro.serve.store_server import ServerThread

FNAME = "model.safetensors"


def _write_model(path, seed, n_tensors=3, n=512):
    rng = np.random.RandomState(seed)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tensors = {f"t{i}": (rng.randn(n) * 0.02).astype(np.float32)
               for i in range(n_tensors)}
    st.save_file(tensors, path)
    with open(path, "rb") as f:
        return f.read()


class _PeerCluster:
    """One local root + two chaos-proxied remote peers, all on disk under
    ``tmp``: the coordinator router sees ``rA`` (in-process) and
    ``pB``/``pC`` (PeerStore -> ChaosProxy -> ServerThread -> ZLLMStore).
    ``backing`` holds every replica's REAL store for direct byte-level
    assertions the wire cannot launder."""

    def __init__(self, tmp, *, replicas=3, write_quorum=2, timeout=5.0):
        self.tmp = tmp
        self.storeA = ZLLMStore(os.path.join(tmp, "A"), workers=1)
        self.backing = OrderedDict([("rA", self.storeA)])
        self.servers, self.proxies, self.peers = {}, {}, {}
        roots = OrderedDict([("rA", self.storeA)])
        for name, sub in (("pB", "B"), ("pC", "C")):
            store = ZLLMStore(os.path.join(tmp, sub), workers=1)
            srv = ServerThread(store).start()
            proxy = ChaosProxy(srv.host, srv.port).start()
            self.backing[name] = store
            self.servers[name] = srv
            self.proxies[name] = proxy
            self.peers[name] = PeerStore(proxy.url, timeout=timeout)
            roots[name] = self.peers[name]
        self.router = StoreRouter(roots, replicas=replicas,
                                  write_quorum=write_quorum)

    def invalidate(self):
        for p in self.peers.values():
            p.invalidate()

    def close(self):
        try:
            self.router.close()  # closes rA and the PeerStore mounts
        finally:
            for srv in self.servers.values():
                try:
                    srv.stop()
                except Exception:
                    pass
            for name, store in self.backing.items():
                if name == "rA":
                    continue
                try:
                    store.close()
                except Exception:
                    pass
            for proxy in self.proxies.values():
                proxy.stop()


def _wait_jobs(router, jobs, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        states = {n: router.roots[n].ingest_job(j) for n, j in jobs.items()}
        if all(s is not None and s["state"] in ("done", "failed")
               for s in states.values()):
            return states
        time.sleep(0.02)
    raise TimeoutError(f"jobs never settled: {states}")


def _drain_workers(router, timeout=60.0):
    """Let every queued job — remote ingest, straggler repair, hint
    drain — finish on every replica."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        pending = []
        for s in router.roots.values():
            try:
                pending += [j for j in s.ingest_jobs(256)
                            if j["state"] in ("queued", "running")]
            except Exception:
                continue  # an unreachable peer's jobs cannot block a drain
        if not pending:
            return
        time.sleep(0.02)
    raise TimeoutError("job workers never drained")


def _put(cl, repo_id, seed, n=512):
    src = os.path.join(cl.tmp, "up", repo_id.replace("/", "_"),
                       f"s{seed}-{FNAME}")
    blob = _write_model(src, seed, n=n)
    rep = cl.router.replicated_enqueue(src, repo_id, FNAME)
    _wait_jobs(cl.router, rep["jobs"])
    return blob, rep


def _assert_converged(cl, oracle):
    """Convergence over the wire: empty index diff, clean fsck on every
    BACKING store, and every live file byte-identical to the oracle on
    every replica — read directly, not through the proxy."""
    cl.invalidate()
    assert cl.router.replica_index_diff() == {}
    for name, store in cl.backing.items():
        rep = store.fsck(repair=False, spot_check=None)
        assert rep.ok, (name, rep.dangling, rep.corrupt)
    for repo_id, blob in oracle.items():
        key = f"{repo_id}/{FNAME}"
        for name, store in cl.backing.items():
            if blob is None:
                assert key not in store.file_index, \
                    f"deleted {key} resurrected on {name}"
            else:
                assert store.retrieve_file(repo_id, FNAME) == blob, \
                    f"live tensor data lost for {repo_id} on {name}"


class _Kill(BaseException):
    """BaseException so no except-Exception handler on the way out can
    soften the simulated crash."""


def _arm(router, point, fired):
    def hook(p):
        if p == point:
            fired.append(p)
            raise _Kill(p)
    router.fault_hook = hook


# ---------------------------------------------------------------------------
# partition -> quorum write -> heal -> one sweep converges all three
# ---------------------------------------------------------------------------

def test_partition_write_heal_sweep_converges_all_three(tmp_path):
    cl = _PeerCluster(str(tmp_path))
    try:
        blob1, rep = _put(cl, "org/base", 1)
        assert sorted(rep["jobs"]) == ["pB", "pC", "rA"]

        cl.proxies["pC"].mode = "drop"  # partition C off the wire
        assert not cl.peers["pC"].probe()
        blob2, rep = _put(cl, "org/part", 2)
        assert rep["failed"] == ["pC"] and len(rep["jobs"]) == 2
        ok, _ = cl.router.await_quorum(rep["jobs"])
        assert ok, "W=2 must be reachable with one peer partitioned"
        _drain_workers(cl.router)  # incl. the straggler repair, which
        # cannot reach the partitioned peer and leaves it divergent
        assert f"org/part/{FNAME}" not in cl.backing["pC"].file_index

        cl.proxies["pC"].mode = "pass"  # heal the wire
        rep2 = cl.router.anti_entropy()
        assert rep2["shipped_versions"] >= 1 and not rep2["errors"]
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/base": blob1, "org/part": blob2})
    finally:
        cl.close()


def test_replicated_delete_tombstones_cross_the_wire(tmp_path):
    cl = _PeerCluster(str(tmp_path))
    try:
        _put(cl, "org/del", 3)
        _drain_workers(cl.router)
        cl.proxies["pB"].mode = "drop"  # this replica misses the delete
        out = cl.router.delete("org/del", FNAME)
        assert out["deleted"] == 1 and out["failed"] == ["pB"]
        assert f"org/del/{FNAME}" in cl.backing["pB"].file_index
        cl.proxies["pB"].mode = "pass"
        rep = cl.router.anti_entropy()
        assert rep["tombstones_applied"] >= 1 and not rep["errors"]
        _assert_converged(cl, {"org/del": None})
    finally:
        cl.close()


# ---------------------------------------------------------------------------
# truncate-mid-body kill: no .part debris after fsck, next sweep adopts
# ---------------------------------------------------------------------------

def test_mid_transfer_kill_leaves_no_part_debris_then_adopts(tmp_path):
    cl = _PeerCluster(str(tmp_path))
    try:
        _put(cl, "org/mid", 4, n=4096)
        _drain_workers(cl.router)
        cl.router.set_root_down("pB")  # pB misses the next generation
        blob2, _ = _put(cl, "org/mid", 5, n=4096)
        _drain_workers(cl.router)
        cl.router.set_root_down("pB", False)

        # every upload connection now dies after ~1.5 KB on the wire: the
        # resumable retry budget (4 attempts) cannot move a ~48 KB
        # container, so the ship fails mid-body and the target keeps a
        # partial ``.part``
        cl.proxies["pB"].mode = "truncate"
        cl.proxies["pB"].truncate_after = 1500
        rep = cl.router.anti_entropy()
        assert rep["errors"], "a truncated ship must surface as a sweep error"
        spool = cl.backing["pB"].spool_dir()
        assert [f for f in os.listdir(spool) if f.endswith(".part")], \
            "mid-body kill left no partial transfer on the target"

        # fsck flags the transfer temp as debris and repair removes it
        fr = cl.backing["pB"].fsck(repair=True, spot_check=None)
        assert fr.ok
        assert any(o.endswith(".part") for o in fr.orphans)
        assert not [f for f in os.listdir(spool) if f.endswith(".part")]

        cl.proxies["pB"].mode = "pass"  # heal: the next sweep completes
        rep2 = cl.router.anti_entropy()
        assert rep2["shipped_versions"] >= 1 and not rep2["errors"]
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/mid": blob2})
        assert cl.backing["pB"].retrieve_file("org/mid", FNAME) == blob2
    finally:
        cl.close()


def test_killed_upload_resumes_from_part_offset(tmp_path):
    """A .part that survives (no fsck in between) is a resume point, not
    garbage: the re-ship continues from the peer's offset instead of
    resending the whole container (asserted via the server-side offset
    re-sync — the second attempt's 409 handshake)."""
    cl = _PeerCluster(str(tmp_path))
    try:
        cl.router.set_root_down("pB")
        blob, _ = _put(cl, "org/res", 6, n=4096)
        _drain_workers(cl.router)
        cl.router.set_root_down("pB", False)
        cl.proxies["pB"].mode = "truncate"
        cl.proxies["pB"].truncate_after = 1500
        rep = cl.router.anti_entropy()
        assert rep["errors"]
        spool = cl.backing["pB"].spool_dir()
        parts = [f for f in os.listdir(spool) if f.endswith(".part")]
        assert parts
        have = os.path.getsize(os.path.join(spool, parts[0]))
        assert have > 0
        cl.proxies["pB"].mode = "pass"
        rep2 = cl.router.anti_entropy()
        assert rep2["shipped_versions"] >= 1 and not rep2["errors"]
        # the .part was consumed by the completed adopt, not re-created
        assert not [f for f in os.listdir(spool) if f.endswith(".part")]
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/res": blob})
    finally:
        cl.close()


# ---------------------------------------------------------------------------
# hinted handoff: targeted re-ship on recovery, never a full sweep
# ---------------------------------------------------------------------------

def test_hinted_handoff_reships_exactly_hinted_keys(tmp_path):
    cl = _PeerCluster(str(tmp_path))
    try:
        blob1, _ = _put(cl, "org/h1", 7)
        _drain_workers(cl.router)

        # an UNRELATED divergence only a full sweep would repair: pB
        # misses org/h3 behind a manual down/up (the manual heal
        # deliberately does not drain its hint)
        cl.router.set_root_down("pB")
        blob3, _ = _put(cl, "org/h3", 8)
        _drain_workers(cl.router)
        cl.router.set_root_down("pB", False)
        assert cl.router.pending_hint_count("pB") == 1

        cl.proxies["pC"].mode = "drop"
        blob2, rep = _put(cl, "org/h2", 9)
        assert rep["failed"] == ["pC"]
        _drain_workers(cl.router)
        assert cl.router.pending_hint_count("pC") == 1
        assert cl.router.health()["pC"]["consecutive_failures"] > 0
        sweeps = cl.router.anti_entropy_sweeps

        # organic recovery: the first success after a failure streak
        # schedules the targeted drain for exactly this peer
        cl.proxies["pC"].mode = "pass"
        cl.router.note_success("pC")
        _drain_workers(cl.router)

        assert cl.router.pending_hint_count("pC") == 0
        assert cl.router.hints_drained >= 1
        assert cl.backing["pC"].retrieve_file("org/h2", FNAME) == blob2
        # targeted, not a sweep: the counter is flat and the unrelated
        # pB divergence (and its hint) are untouched
        assert cl.router.anti_entropy_sweeps == sweeps
        assert cl.router.pending_hint_count("pB") == 1
        assert f"org/h3/{FNAME}" not in cl.backing["pB"].file_index
        cl.invalidate()
        assert cl.router.replica_index_diff(repos=["org/h3"]) != {}

        # a full sweep settles the rest; the stale pB hint then drains
        # as already-converged debt
        rep2 = cl.router.anti_entropy()
        assert not rep2["errors"]
        out = cl.router.drain_hints()
        assert out["kept"] == 0 and not out["errors"]
        assert cl.router.pending_hint_count() == 0
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/h1": blob1, "org/h2": blob2,
                               "org/h3": blob3})
    finally:
        cl.close()


def test_hint_for_deleted_key_is_void_not_resurrected(tmp_path):
    """Regression: a hint whose write was deleted before the drain must
    be voided, NOT re-ingested from the staged spool bytes — the requeue
    would mint a fresh generation on the target and plant a divergent
    same-``(key, gen)`` container (or, above the marker's generation,
    resurrect the deleted key on the next sweep)."""
    cl = _PeerCluster(str(tmp_path))
    try:
        _put(cl, "org/void", 14)
        _drain_workers(cl.router)
        cl.proxies["pC"].mode = "drop"
        _put(cl, "org/void", 15)  # pC misses gen1: hint recorded
        _drain_workers(cl.router)
        assert cl.router.pending_hint_count("pC") == 1
        out = cl.router.delete("org/void", FNAME)  # pC misses this too
        assert out["failed"] == ["pC"]

        cl.proxies["pC"].mode = "pass"
        drained = cl.router.drain_hints()
        assert drained["drained"] == 1 and drained["requeued"] == 0, \
            "a deleted key's hint must void, not requeue its stale bytes"
        rep = cl.router.anti_entropy()
        assert not rep["errors"]
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/void": None})
    finally:
        cl.close()


def test_hints_for_unreachable_peer_are_kept(tmp_path):
    cl = _PeerCluster(str(tmp_path))
    try:
        cl.proxies["pC"].mode = "drop"
        _put(cl, "org/keep", 10)
        _drain_workers(cl.router)
        assert cl.router.pending_hint_count("pC") == 1
        out = cl.router.drain_hints()  # target still unreachable
        assert out["kept"] == 1 and out["drained"] == 0
        assert cl.router.pending_hint_count("pC") == 1
    finally:
        cl.close()


# ---------------------------------------------------------------------------
# crash injection at the new wire fault points
# ---------------------------------------------------------------------------

def test_ship_killed_mid_body_then_resumes_and_heals(tmp_path):
    """``peer.ship_mid_body``: the coordinator dies mid-upload (after the
    first block hit the wire). The target holds at most a resumable
    ``.part``; the next sweep completes the adopt and converges."""
    cl = _PeerCluster(str(tmp_path))
    try:
        cl.router.set_root_down("pC")
        blob, _ = _put(cl, "org/k1", 11, n=4096)
        _drain_workers(cl.router)
        cl.router.set_root_down("pC", False)
        fired = []
        _arm(cl.router, "peer.ship_mid_body", fired)
        with pytest.raises(_Kill):
            cl.router.anti_entropy()
        assert fired == ["peer.ship_mid_body"]
        cl.router.fault_hook = None

        rep = cl.router.anti_entropy()
        assert rep["shipped_versions"] >= 1 and not rep["errors"]
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/k1": blob})
        spool = cl.backing["pC"].spool_dir()
        assert not [f for f in os.listdir(spool) if f.endswith(".part")]
    finally:
        cl.close()


def test_adopt_crash_before_index_persist_heals_on_restart(tmp_path):
    """``peer.adopt_pre_persist``: the RECEIVING peer dies between
    adopting the container bytes and persisting its index — a hard
    process crash. The restarted peer holds orphaned container bytes and
    no record; fsck treats the orphan as debris and the next sweep
    re-ships cleanly."""
    cl = _PeerCluster(str(tmp_path))
    storeC2 = srvC2 = None
    try:
        # prior converged state on C: fsck's empty-graph safety valve
        # (it refuses orphan deletes on an unloaded index) must not
        # conflate a crashed-but-real store with a missing one
        blob0, _ = _put(cl, "org/pre", 19)
        _drain_workers(cl.router)
        cl.router.set_root_down("pC")
        blob, _ = _put(cl, "org/k2", 12)
        _drain_workers(cl.router)
        cl.router.set_root_down("pC", False)

        fired = []

        def hook(point):
            if point == "peer.adopt_pre_persist":
                fired.append(point)
                raise RuntimeError(f"injected fault: {point}")

        cl.backing["pC"].fault_hook = hook
        rep = cl.router.anti_entropy()
        assert fired == ["peer.adopt_pre_persist"]
        assert rep["errors"], "the poisoned adopt must surface as an error"
        cl.backing["pC"].fault_hook = None

        # hard-crash peer C: abandon the live store WITHOUT close() (so
        # nothing flushes), restart it from disk on a fresh port, and
        # re-point the proxy at the restarted process
        cl.servers["pC"].stop()
        storeC2 = ZLLMStore(os.path.join(str(tmp_path), "C"), workers=1)
        storeC2.load_index()
        assert f"org/k2/{FNAME}" not in storeC2.file_index, \
            "the record must not survive a crash before the index persist"
        srvC2 = ServerThread(storeC2).start()
        cl.proxies["pC"].upstream = (srvC2.host, srvC2.port)
        cl.peers["pC"].invalidate()
        assert storeC2.fsck(repair=True, spot_check=None).ok

        rep2 = cl.router.anti_entropy()
        assert not rep2["errors"]
        cl.backing["pC"] = storeC2
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/pre": blob0, "org/k2": blob})
    finally:
        cl.close()
        if srvC2 is not None:
            srvC2.stop()


def test_hint_drain_killed_before_log_persist_replays_idempotently(tmp_path):
    """``hint.pre_drain_persist``: the drain dies after the re-ship
    landed but before the hint log dropped the entries. The replay
    re-drains the same hints; idempotent shipping converges to the same
    state and the log finally empties."""
    cl = _PeerCluster(str(tmp_path))
    try:
        cl.proxies["pC"].mode = "drop"
        blob, rep = _put(cl, "org/k3", 13)
        assert rep["failed"] == ["pC"]
        _drain_workers(cl.router)
        assert cl.router.pending_hint_count("pC") == 1

        cl.proxies["pC"].mode = "pass"
        fired = []
        _arm(cl.router, "hint.pre_drain_persist", fired)
        with pytest.raises(_Kill):
            cl.router.drain_hints()
        assert fired == ["hint.pre_drain_persist"]
        cl.router.fault_hook = None

        # the ship landed; the debt did not clear
        assert cl.router.pending_hint_count("pC") == 1
        assert cl.backing["pC"].retrieve_file("org/k3", FNAME) == blob

        out = cl.router.drain_hints()  # the replay settles the same debt
        assert out["drained"] == 1 and not out["errors"]
        assert cl.router.pending_hint_count("pC") == 0
        _drain_workers(cl.router)
        _assert_converged(cl, {"org/k3": blob})
    finally:
        cl.close()


# ---------------------------------------------------------------------------
# property: random op/partition interleavings converge to the
# single-node oracle
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(stt.lists(stt.integers(min_value=0, max_value=9999),
                 min_size=3, max_size=7))
def test_random_interleavings_converge_to_single_node_oracle(ops):
    """Any interleaving of put / delete / partition / heal across the
    three peers must, after heal + drain + one sweep, converge every
    replica to the state a single never-partitioned node reaches from
    the same accepted op sequence: identical per-key generations,
    tombstone-LWW deletions, byte-identical reads."""
    tmp = tempfile.mkdtemp(prefix="zllm-peer-prop-")
    cl = _PeerCluster(tmp, write_quorum=1, timeout=2.0)
    oracle = ZLLMStore(os.path.join(tmp, "oracle"), workers=0)
    repos = ["org/p0", "org/p1"]
    try:
        for i, v in enumerate(ops):
            op = v % 5
            repo = repos[(v // 5) % len(repos)]
            peer = ("pB", "pC")[(v // 10) % 2]
            if op in (0, 1):  # put (seed unique per op: no cross-gen dedup)
                # one dir per op: the oracle's ingest_file derives the key
                # from the basename, which must stay model.safetensors
                src = os.path.join(tmp, "up", str(i), FNAME)
                _write_model(src, seed=v * 100 + i, n=64)
                rep = cl.router.replicated_enqueue(src, repo, FNAME)
                _wait_jobs(cl.router, rep["jobs"])
                oracle.ingest_file(src, repo)
            elif op == 2:  # delete (rA is never partitioned: always lands)
                cl.router.delete(repo, FNAME)
                oracle.delete_file(repo, FNAME)
            elif op == 3:  # partition one peer off the wire
                cl.proxies[peer].mode = "drop"
            else:  # heal every partition
                for p in cl.proxies.values():
                    p.mode = "pass"

        for p in cl.proxies.values():
            p.mode = "pass"
        _drain_workers(cl.router)
        cl.router.drain_hints()
        rep = cl.router.anti_entropy()
        assert not rep["errors"], rep["errors"]
        _drain_workers(cl.router)

        cl.invalidate()
        assert cl.router.replica_index_diff() == {}
        for repo in repos:
            key = f"{repo}/{FNAME}"
            orec = oracle.file_index.get(key)
            for name, store in cl.backing.items():
                rec = store.file_index.get(key)
                if orec is None:
                    assert rec is None, \
                        f"{key} on {name}: oracle deleted, replica kept it"
                else:
                    assert rec is not None, f"{key} lost on {name}"
                    assert rec["gen"] == orec["gen"], \
                        f"{key} on {name}: gen {rec['gen']} != " \
                        f"oracle {orec['gen']}"
                    assert store.retrieve_file(repo, FNAME) == \
                        oracle.retrieve_file(repo, FNAME), \
                        f"{key} on {name}: bytes diverge from the oracle"
    finally:
        try:
            cl.close()
        finally:
            oracle.close()
            shutil.rmtree(tmp, ignore_errors=True)
