"""The dtype-crossing quantized delta lane (bitxq) + hub-corpus ground truth.

Three layers under test:

* **Codec** — ``bitxq`` dequantize-predict-residual round trips int8 tensors
  bit-exactly against their float base, beats standalone coding when the
  repack sits on the predicted grid, and downgrades to raw/stored when the
  "base" is unrelated noise.
* **Store** — quantized repos (int8 tensors + scale companions, declared
  ``base_model``) ingest through the bitxq lane, survive save/load, gc and
  compact (the stamp — base_dtype/qscale_bits/qzero_point — must be copied
  when compaction rewrites records), and decode bit-identically on the
  numpy and jax backends.
* **Ground truth** — the corpus generator's ``families.json`` labels are
  what ``score_family_clustering`` turns into the CI-gated
  ``zllm.cluster.family_f1`` metric: bit-distance clustering must recover
  the generator's families with and without declared metadata, and a
  quantized member must form its own singleton (dtype crossing defeats
  bit distance BY DESIGN — metadata is the store's path for those repos).
"""

import json
import os

import ml_dtypes
import numpy as np
import pytest

from benchmarks.corpus import (CorpusSpec, make_base_tensors, make_corpus,
                               make_finetune, make_quantized_int4,
                               make_quantized_int8)
from repro.core.bitx import JaxBackend, TensorRecord
from repro.core.codecs import CodecRuntime, EncodeInput, get_codec
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st


def _spec(**kw):
    base = dict(n_families=2, finetunes_per_family=1, reuploads_per_family=0,
                lora_per_family=0, vocab_expanded_per_family=0,
                checkpoints_per_family=0, quantized_per_family=1,
                n_layers=1, d_model=48, d_ff=96, vocab=192, seed=13)
    base.update(kw)
    return CorpusSpec(**base)


def _bf16_base(n=4096, seed=5):
    rng = np.random.RandomState(seed)
    return (rng.randn(n) * 0.02).astype(ml_dtypes.bfloat16)


def _int8_repack(base_bf16):
    q = make_quantized_int8({"t": base_bf16})
    return q["t"]


# ---------------------------------------------------------------------------
# Codec layer
# ---------------------------------------------------------------------------

def test_bitxq_pure_repack_all_zero_residual_roundtrip():
    """An int8 repack of its own base lands exactly on the predicted grid:
    the XOR residual is all zero, the frames are far smaller than standalone
    coding of the int8 bytes, and decode recovers them bit-exactly."""
    rt = CodecRuntime()
    base = _bf16_base()
    q = _int8_repack(base)
    out = get_codec("bitxq").encode(
        rt, EncodeInput(data=q, base=base.view(np.uint16).tobytes(),
                        base_dtype="BF16"))
    codec, frames, raw, extras = out
    assert codec == "bitxq" and raw == q.nbytes
    assert extras["base_dtype"] == "BF16" and extras["qzero_point"] == 0
    # the scale bit pattern must decode to a positive finite float32
    scale = np.array(extras["qscale_bits"], np.uint32).view(np.float32)[()]
    assert np.isfinite(scale) and scale > 0
    standalone = len(rt.compress(q.tobytes()))
    assert sum(len(f) for f in frames) < standalone / 5

    rec = TensorRecord("t", "I8", q.shape, "bitxq", "bh", "sh",
                       [len(f) for f in frames], raw, **extras)
    got = get_codec("bitxq").decode(
        rt, rec, frames, np.dtype(np.int8),
        lambda h: base.view(np.uint16).tobytes(), None)
    assert got.dtype == np.int8 and (got == q).all()


def test_bitxq_quantized_finetune_roundtrip():
    """Quantizing a FINE-TUNE but predicting from the family BASE leaves a
    nonzero residual; the lane must still round trip bit-exactly."""
    rt = CodecRuntime()
    spec = _spec()
    rng = np.random.RandomState(spec.seed)
    base = _bf16_base(2048)
    ft = (base.astype(np.float32)
          + (rng.randn(base.size) * 0.005).astype(np.float32)
          ).astype(ml_dtypes.bfloat16)
    q = _int8_repack(ft)  # quantized on the fine-tune's own grid
    out = get_codec("bitxq").encode(
        rt, EncodeInput(data=q, base=base.view(np.uint16).tobytes(),
                        base_dtype="BF16"))
    assert out[0] == "bitxq"
    codec, frames, raw, extras = out
    rec = TensorRecord("t", "I8", q.shape, "bitxq", "bh", "sh",
                       [len(f) for f in frames], raw, **extras)
    got = get_codec("bitxq").decode(
        rt, rec, frames, np.dtype(np.int8),
        lambda h: base.view(np.uint16).tobytes(), None)
    assert (got == q).all()


def test_bitxq_downgrades_on_unrelated_base():
    """Predicting from NOISE leaves a dense residual; the encoder must fall
    back to standalone raw/stored coding (3-tuple, no stamp) rather than
    ship a delta bigger than the data."""
    rt = CodecRuntime()
    rng = np.random.RandomState(9)
    q = rng.randint(-127, 128, 4096).astype(np.int8)
    noise = (rng.randn(4096) * 0.02).astype(ml_dtypes.bfloat16)
    out = get_codec("bitxq").encode(
        rt, EncodeInput(data=q, base=noise.view(np.uint16).tobytes(),
                        base_dtype="BF16"))
    assert out[0] in ("raw", "stored") and len(out) == 3


def test_bitxq_nonfinite_base_elements_are_deterministic():
    """NaN/Inf in the base must quantize to a well-defined prediction (zeroed
    before rint) — int8-casting NaN is platform-dependent, which would break
    the cross-backend container-determinism guarantee."""
    rt = CodecRuntime()
    base = _bf16_base(1024)
    base[::100] = np.float32("nan")
    base[1::100] = np.float32("inf")
    q = _int8_repack(base)
    out = get_codec("bitxq").encode(
        rt, EncodeInput(data=q, base=base.view(np.uint16).tobytes(),
                        base_dtype="BF16"))
    codec, frames, raw, extras = out
    rec = TensorRecord("t", "I8", q.shape, "bitxq", "bh", "sh",
                       [len(f) for f in frames], raw, **extras)
    got = get_codec("bitxq").decode(
        rt, rec, frames, np.dtype(np.int8),
        lambda h: base.view(np.uint16).tobytes(), None)
    assert (got == q).all()


def test_tensor_record_stamp_json_roundtrip():
    """The quant stamp survives index serialization; records WITHOUT a stamp
    serialize exactly as before (old containers stay byte-identical)."""
    r = TensorRecord("t", "I8", (4,), "bitxq", "bh", "sh", [3], 4,
                     base_dtype="BF16", qscale_bits=1065353216, qzero_point=0)
    j = r.to_json()
    back = TensorRecord.from_json(j)
    assert (back.base_dtype, back.qscale_bits, back.qzero_point) == \
        ("BF16", 1065353216, 0)
    plain = TensorRecord("t", "F32", (4,), "zipnn", None, "sh", [3], 16)
    assert not {"base_dtype", "qscale_bits", "qzero_point"} & set(plain.to_json())


# ---------------------------------------------------------------------------
# Store layer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qcorpus(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("qhub"))
    manifest = make_corpus(root, _spec())
    families = json.load(open(os.path.join(root, "families.json")))
    return root, manifest, families


def _ingest_all(store, root, manifest):
    store.ingest_repos([(os.path.join(root, rid), rid) for rid, _ in manifest])


def test_store_quantized_repo_takes_bitxq_lane(tmp_path, qcorpus):
    root, manifest, _ = qcorpus
    store = ZLLMStore(str(tmp_path / "s"))
    _ingest_all(store, root, manifest)
    qres = [r for r in store.results if "int8" in r.repo_id]
    assert qres and all(r.n_bitxq > 0 for r in qres)
    assert all(r.base_source == "metadata" for r in qres)
    # the delta lane must make the int8 repack measurably smaller than
    # standalone: the repack of the base itself is near-all-dedup-or-zero
    repack = next(r for r in qres if r.repo_id.startswith("quant0-0"))
    assert repack.reduction > 0.5
    for rid, _ in manifest:
        orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
        assert store.retrieve_file(rid, "model.safetensors") == orig


def test_store_bitxq_survives_reload(tmp_path, qcorpus):
    root, manifest, _ = qcorpus
    s1 = ZLLMStore(str(tmp_path / "p"))
    _ingest_all(s1, root, manifest)
    s1.save_index()
    s2 = ZLLMStore(str(tmp_path / "p"))
    assert s2.load_index()
    for rid, _ in manifest:
        orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
        assert s2.retrieve_file(rid, "model.safetensors") == orig


def test_store_bitxq_survives_gc_and_compact(tmp_path, qcorpus):
    """Compaction rewrites still-referenced records into fresh containers —
    it must copy the quant stamp (base_dtype/qscale_bits/qzero_point) and
    keep the base tensor reachable, or decode breaks afterwards."""
    root, manifest, _ = qcorpus
    store = ZLLMStore(str(tmp_path / "c"))
    _ingest_all(store, root, manifest)
    # supersede a fine-tune generation so compact has something to do, then
    # delete a quantized repo so gc chews on bitxq bookkeeping too
    ft = next(rid for rid, kind in manifest if kind == "finetune")
    store.ingest_repo(os.path.join(root, ft), ft)
    gone = next(rid for rid, kind in manifest if kind == "quantized_int8"
                and rid.endswith("-1-0"))
    store.delete_repo(gone)
    store.gc()
    store.compact()
    rep = store.fsck(repair=False, spot_check=None)
    assert rep.ok
    for rid, _ in manifest:
        if rid == gone:
            continue
        orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
        assert store.retrieve_file(rid, "model.safetensors") == orig


@pytest.mark.skipif(not JaxBackend.available(), reason="jax not installed")
def test_store_bitxq_containers_bit_identical_numpy_vs_jax(tmp_path, qcorpus):
    """The bitxq prediction is pinned to host numpy precisely so the
    container bytes cannot depend on the backend: same corpus, numpy vs
    jax stores, every container file byte-identical."""
    import hashlib
    root, manifest, _ = qcorpus
    digests = {}
    for backend in ("numpy", "jax"):
        s = ZLLMStore(str(tmp_path / backend), backend=backend)
        _ingest_all(s, root, manifest)
        h = hashlib.sha256()
        croot = str(tmp_path / backend)
        for dirpath, _, files in sorted(os.walk(croot)):
            for fn in sorted(files):
                rel = os.path.relpath(os.path.join(dirpath, fn), croot)
                h.update(rel.encode())
                h.update(open(os.path.join(dirpath, fn), "rb").read())
        digests[backend] = h.hexdigest()
        for rid, _ in manifest:
            orig = open(os.path.join(root, rid, "model.safetensors"),
                        "rb").read()
            assert s.retrieve_file(rid, "model.safetensors") == orig
    assert digests["numpy"] == digests["jax"]


# ---------------------------------------------------------------------------
# Generator ground truth + clustering accuracy
# ---------------------------------------------------------------------------

def test_families_json_covers_every_repo(qcorpus):
    root, manifest, families = qcorpus
    assert set(families) == {rid for rid, _ in manifest}
    assert all(v.startswith("family-") for v in families.values())


def test_clustering_recovers_generator_truth(qcorpus):
    """F1 == 1.0 against ground truth over the full-weight same-signature
    kinds — the exact computation behind zllm.cluster.family_f1."""
    from repro.core.clustering import score_family_clustering
    root, manifest, families = qcorpus
    paths, labels = [], []
    for rid, kind in manifest:
        if kind in ("base", "finetune", "reupload", "checkpoint"):
            paths.append(os.path.join(root, rid, "model.safetensors"))
            labels.append(families[rid])
    s = score_family_clustering(paths, labels)
    assert s["f1"] == 1.0 and s["n_clusters"] == 2


def test_clustering_recovers_truth_without_metadata(tmp_path):
    """metadata_prob=0: no fine-tune declares base_model, so family recovery
    rests entirely on sampled bit distance — the paper's §A.0.1 claim.
    sigma_delta sits at the LOW end of the paper's band (E[D] ≈ 3.1 bits at
    σw=0.02, comfortably under the 4-bit threshold): at the band's middle
    the per-file mean rides the threshold and recall is a coin flip, which
    is the paper's 93.5%-not-100% point, not a regression to gate on."""
    from repro.core.clustering import score_family_clustering
    root = str(tmp_path / "nometa")
    manifest = make_corpus(root, _spec(metadata_prob=0.0, sigma_delta=0.001,
                                       finetunes_per_family=2))
    families = json.load(open(os.path.join(root, "families.json")))
    paths, labels = [], []
    for rid, kind in manifest:
        if kind in ("base", "finetune"):
            paths.append(os.path.join(root, rid, "model.safetensors"))
            labels.append(families[rid])
    s = score_family_clustering(paths, labels)
    assert s["f1"] == 1.0


def test_quantized_member_clusters_as_singleton(qcorpus):
    """An int8 repack crosses the dtype/shape signature, so bit distance
    CANNOT place it (singleton component) — documenting why quantized repos
    must declare base_model and why family_f1 scoring excludes them."""
    from repro.core.clustering import cluster_models
    root, manifest, families = qcorpus
    paths = []
    qi = None
    for rid, kind in manifest:
        if kind in ("base", "finetune"):
            paths.append(os.path.join(root, rid, "model.safetensors"))
        elif kind == "quantized_int8" and qi is None:
            qi = len(paths)
            paths.append(os.path.join(root, rid, "model.safetensors"))
    comps = cluster_models(paths)
    assert [qi] in comps


def test_score_family_clustering_validates_lengths():
    from repro.core.clustering import score_family_clustering
    with pytest.raises(ValueError, match="labels"):
        score_family_clustering(["a"], ["x", "y"])


# ---------------------------------------------------------------------------
# Hub-tier generator shapes
# ---------------------------------------------------------------------------

def test_sharded_family_writes_numbered_shards(tmp_path):
    root = str(tmp_path / "sh")
    make_corpus(root, _spec(sharded_families=1, shards=3))
    files = sorted(os.listdir(os.path.join(root, "org0/base-model-0")))
    assert "model-00001-of-00003.safetensors" in files
    assert sum(f.endswith(".safetensors") for f in files) == 3
    # family 1 stays single-file
    assert os.path.exists(os.path.join(root, "org1/base-model-1",
                                       "model.safetensors"))
    # shards partition the tensor set: names disjoint, union == unsharded set
    names = []
    for f in files:
        if f.endswith(".safetensors"):
            names += list(st.load_file(
                os.path.join(root, "org0/base-model-0", f)))
    assert len(names) == len(set(names))


def test_arch_templates_moe_and_ssm(tmp_path):
    """MoE configs get per-expert mats + router, SSM configs a Mamba mixer
    stack with float32 state params — structural signatures from the real
    repro.configs entries at scaled-down widths."""
    rng = np.random.RandomState(0)
    from repro.configs import get_config
    spec = _spec()
    moe = make_base_tensors(spec, rng, get_config("mixtral-8x7b"))
    assert "model.layers.0.block_sparse_moe.gate.weight" in moe
    assert "model.layers.0.block_sparse_moe.experts.0.w1.weight" in moe
    ssm = make_base_tensors(spec, rng, get_config("falcon-mamba-7b"))
    assert "model.layers.0.mixer.in_proj.weight" in ssm
    assert ssm["model.layers.0.mixer.A_log"].dtype == np.float32
    dense = make_base_tensors(spec, rng, None)
    assert "model.layers.0.mlp.gate_proj.weight" in dense


def test_int4_pack_halves_bytes(tmp_path):
    base = {"w": _bf16_base(1000)}
    q4 = make_quantized_int4(base)
    assert q4["w"].dtype == np.uint8 and q4["w"].size == 500
    assert q4["w.quant_scale"].dtype == np.float32


def test_popularity_skew_preserves_budget_and_floor():
    from benchmarks.corpus import _finetune_counts
    flat = _finetune_counts(_spec(n_families=4, finetunes_per_family=3))
    assert flat == [3, 3, 3, 3]
    skewed = _finetune_counts(_spec(n_families=4, finetunes_per_family=3,
                                    popularity_skew=0.8))
    assert sum(skewed) == 12 and min(skewed) >= 1
    assert skewed[0] > skewed[-1]  # family 0 is the popular one


def test_quantized_repos_always_declare_base(tmp_path):
    """Even at metadata_prob=0 the quantized repos carry base_model — the
    dtype crossing leaves metadata as the only family signal."""
    root = str(tmp_path / "qm")
    manifest = make_corpus(root, _spec(metadata_prob=0.0))
    for rid, kind in manifest:
        if kind == "quantized_int8":
            readme = open(os.path.join(root, rid, "README.md")).read()
            assert "base_model:" in readme
