"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED config of
the same family, run one forward/train step on CPU, assert output shapes and
no NaNs; plus prefill→decode consistency against the full forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeCell
from repro.models.api import get_model, init_params, make_batch
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.train.step import make_train_step

TRAIN_CELL = ShapeCell("t", "train", 32, 4, microbatches=2)
PREFILL_CELL = ShapeCell("p", "prefill", 16, 2)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, TRAIN_CELL, rng)

    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    opt = make_optimizer(OptimizerConfig(name=cfg.optimizer, lr=1e-3, warmup_steps=1))
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, microbatches=TRAIN_CELL.microbatches))
    new_params, new_state, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # every parameter stays finite and at least one changed
    changed = False
    for k in params:
        assert bool(jnp.all(jnp.isfinite(new_params[k].astype(jnp.float32)))), k
        if not np.array_equal(np.asarray(new_params[k]), np.asarray(params[k])):
            changed = True
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_decreases(arch, rng):
    """A few steps on one repeated batch must reduce the loss (learnability)."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, TRAIN_CELL, rng)
    opt = make_optimizer(OptimizerConfig(name=cfg.optimizer, lr=3e-3, warmup_steps=0,
                                         weight_decay=0.0))
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    first = None
    for _ in range(5):
        params, state, m = step(params, state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first, f"{arch}: loss did not decrease"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, rng):
    """Greedy decode after prefill must match the full-forward logits."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = init_params(cfg, rng)
    S = PREFILL_CELL.seq_len
    batch = make_batch(cfg, PREFILL_CELL, rng)

    logits_p, cache = model.prefill(params, batch)
    assert logits_p.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits_p)))

    # extend the sequence by one token and compare decode vs re-prefill
    next_tok = jnp.argmax(logits_p, -1).astype(jnp.int32)[:, None]
    # grow caches for one more position where needed
    grown = dict(cache)
    for k, spec in model.cache_templates(2, S).items():
        if "sp" in spec.axes:
            ax = spec.axes.index("sp")
            pad = [(0, 0)] * cache[k].ndim
            pad[ax] = (0, 1)
            grown[k] = jnp.pad(cache[k], pad)
    logits_d, _ = model.decode_step(params, {"tokens": next_tok}, grown)

    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    if "patch_embeds" in batch2:
        batch2["patch_embeds"] = jnp.pad(batch2["patch_embeds"], ((0, 0), (0, 1), (0, 0)))
        batch2["positions3"] = jnp.pad(batch2["positions3"], ((0, 0), (0, 0), (0, 1)),
                                       constant_values=S)
    logits_f, _ = model.prefill(params, batch2)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_f),
                               rtol=2e-2, atol=2e-2)


def test_cells_for_long_context_policy():
    """long_500k runs only for sub-quadratic archs, per assignment."""
    from repro.configs import cells_for
    runs_long = {a for a in ARCH_IDS
                 if any(c.name == "long_500k" for c in cells_for(get_config(a)))}
    assert runs_long == {"mixtral-8x7b", "falcon-mamba-7b", "zamba2-2.7b"}
    for a in ARCH_IDS - runs_long if isinstance(ARCH_IDS, set) else set(ARCH_IDS) - runs_long:
        assert get_config(a).long_skip_reason


def test_param_counts_match_published():
    expect = {"qwen2-vl-7b": (7.0e9, 8.2e9), "phi4-mini-3.8b": (3.5e9, 4.2e9),
              "deepseek-coder-33b": (31e9, 35e9), "qwen2-7b": (7.0e9, 8.2e9),
              "mixtral-8x7b": (45e9, 48e9), "grok-1-314b": (300e9, 330e9),
              "falcon-mamba-7b": (6.9e9, 7.8e9), "zamba2-2.7b": (2.1e9, 2.9e9),
              "whisper-medium": (0.6e9, 0.9e9)}
    for arch, (lo, hi) in expect.items():
        n = get_model(get_config(arch)).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
