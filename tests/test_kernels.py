"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis roundtrip properties.

Everything here is lossless bit manipulation — assertions are EXACT equality.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as stt

from repro.kernels import bitx_xor, byte_planes, hamming, ops, ref

SHAPES = [(1, 1024), (4, 1024), (256, 1024), (3, 2048), (257, 1024)]
DTYPES = [jnp.uint16, jnp.uint32]


def _rand_bits(key, shape, dtype):
    bits = jax.random.randint(key, shape, 0, 2**16, jnp.uint32)
    if dtype == jnp.uint32:
        bits = bits * 65536 + jax.random.randint(key, shape, 0, 2**16, jnp.uint32)
    return bits.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_xor_split_matches_oracle(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = _rand_bits(k1, shape, dtype)
    b = _rand_bits(k2, shape, dtype)
    rows = shape[0]
    br = rows if rows in (1, 3, 257) else min(256, rows)
    if rows % br:
        br = 1
    got = bitx_xor.xor_split_2d(a, b, block_rows=br, interpret=True)
    want = ref.xor_split_planes(a, b)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_merge_xor_roundtrip(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    base = _rand_bits(k1, shape, dtype)
    ft = _rand_bits(k2, shape, dtype)
    br = 1 if shape[0] % 256 else 256
    planes = bitx_xor.xor_split_2d(base, ft, block_rows=br, interpret=True)
    back = bitx_xor.merge_xor_2d(planes, base, block_rows=br, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(ft))


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_hamming_matches_oracle(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = _rand_bits(k1, shape, dtype)
    b = _rand_bits(k2, shape, dtype)
    br = 1 if shape[0] % 256 else 256
    total = hamming.hamming_total_2d(a, b, block_rows=br, interpret=True)
    want = int(ref.hamming_total(a, b))
    assert total == want
    # numpy ground truth
    npw = int(np.bitwise_count(np.asarray(a) ^ np.asarray(b)).astype(np.uint64).sum())
    assert total == npw


@pytest.mark.parametrize("dtype", DTYPES)
def test_byte_planes_roundtrip(dtype):
    x = _rand_bits(jax.random.PRNGKey(3), (8, 1024), dtype)
    planes = byte_planes.split_2d(x, block_rows=8, interpret=True)
    back = byte_planes.merge_2d(planes, dtype, block_rows=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    want = ref.byte_split(x)
    for g, w in zip(planes, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# ops.py public API: arbitrary shapes/floats, pallas vs jnp-ref vs numpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (33, 5), (2, 3, 129), (1025,)])
@pytest.mark.parametrize("dtype", ["bfloat16", "float32"])
def test_ops_encode_decode_roundtrip(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    base = (jax.random.normal(k1, shape, jnp.float32) * 0.02).astype(dtype)
    ft = (base.astype(jnp.float32)
          + jax.random.normal(k2, shape, jnp.float32) * 0.005).astype(dtype)
    for use_pallas in (True, False):
        planes = ops.bitx_encode_planes(base, ft, use_pallas=use_pallas)
        out = ops.bitx_decode_planes(planes, base, use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ops.to_bit_view(ft)))


def test_ops_agree_with_numpy_path():
    """Device path and host (paper-C++-equivalent) path are bit-identical."""
    from repro.core.bitx import xor_delta_planes_np
    rng = np.random.RandomState(0)
    base = rng.randn(1000).astype(np.float32)
    ft = (base + rng.randn(1000).astype(np.float32) * 1e-3)
    dev = ops.bitx_encode_planes(jnp.asarray(base), jnp.asarray(ft), use_pallas=True)
    host = xor_delta_planes_np(base, ft)
    for d, h in zip(dev, host):
        np.testing.assert_array_equal(np.asarray(d), h)


@settings(max_examples=30, deadline=None)
@given(stt.lists(stt.floats(width=32, allow_nan=True, allow_infinity=True),
                 min_size=1, max_size=300))
def test_property_bitx_roundtrip_any_floats(xs):
    """BitX is lossless for ANY bit pattern, including NaN/Inf payloads."""
    base = np.asarray(xs, np.float32)
    ft = base[::-1].copy()
    planes = ops.bitx_encode_planes(jnp.asarray(base), jnp.asarray(ft), use_pallas=True)
    out = ops.bitx_decode_planes(planes, jnp.asarray(base), use_pallas=True)
    np.testing.assert_array_equal(np.asarray(out), ft.view(np.uint32))


@settings(max_examples=20, deadline=None)
@given(stt.integers(1, 5000), stt.integers(0, 2**32 - 1))
def test_property_hamming_symmetry_and_identity(n, seed):
    rng = np.random.RandomState(seed % 2**31)
    a = rng.randint(0, 2**16, n).astype(np.uint16)
    b = rng.randint(0, 2**16, n).astype(np.uint16)
    ja, jb = jnp.asarray(a), jnp.asarray(b)
    assert ops.hamming_total(ja, ja) == 0
    assert ops.hamming_total(ja, jb) == ops.hamming_total(jb, ja)
    assert ops.bit_distance(ja, jb) <= 16.0
