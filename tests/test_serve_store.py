"""Async retrieval engine + HTTP store server (repro.serve.store_server).

Covers the serving acceptance criteria: >= 8 concurrent retrievals with
responses byte-identical to direct ZLLMStore reads — including while a
concurrent gc() runs (read-gate snapshot isolation) — single-flight
deduplication of concurrent decodes, and read_gen cache rollover on
re-registration during serving.
"""

import asyncio
import hashlib
import json
import os
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st
from repro.serve.singleflight import SingleFlight
from repro.serve.store_server import RetrievalEngine, ServerThread


def _write_model(path, rng, n_tensors=5, n=2048, scale=0.02):
    tensors = {f"model.t{i}.weight": (rng.randn(n) * scale).astype(np.float32)
               for i in range(n_tensors)}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path)
    return tensors


def _write_finetune(path, base_tensors, rng, sigma=1e-3):
    ft = {k: (v + rng.randn(*v.shape).astype(np.float32) * sigma).astype(np.float32)
          for k, v in base_tensors.items()}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(ft, path)
    return ft


@pytest.fixture
def served_store(tmp_path):
    """Store with one family (base + 2 fine-tunes), an unrelated standalone
    model, and a deletable victim — plus the original bytes per repo."""
    rng = np.random.RandomState(42)
    repos = {}
    base_dir = str(tmp_path / "hub" / "org" / "base")
    base = _write_model(os.path.join(base_dir, "model.safetensors"), rng)
    repos["org/base"] = base_dir
    for k in range(2):
        d = str(tmp_path / "hub" / f"u{k}" / "ft")
        _write_finetune(os.path.join(d, "model.safetensors"), base, rng)
        repos[f"u{k}/ft"] = d
    other_dir = str(tmp_path / "hub" / "org" / "other")
    _write_model(os.path.join(other_dir, "model.safetensors"),
                 np.random.RandomState(7), scale=1.0)
    repos["org/other"] = other_dir
    victim_dir = str(tmp_path / "hub" / "org" / "victim")
    _write_model(os.path.join(victim_dir, "model.safetensors"),
                 np.random.RandomState(9), scale=1.0)
    repos["org/victim"] = victim_dir

    store = ZLLMStore(str(tmp_path / "store"), workers=2)
    for rid, d in repos.items():
        store.ingest_file(os.path.join(d, "model.safetensors"), rid,
                          declared_base="org/base" if rid.startswith("u") else None)
    originals = {rid: open(os.path.join(d, "model.safetensors"), "rb").read()
                 for rid, d in repos.items()}
    yield store, originals
    store.close()


def _http_get(host, port, path):
    with urllib.request.urlopen(f"http://{host}:{port}{path}", timeout=30) as r:
        return r.status, dict(r.headers), r.read()


# ---------------------------------------------------------------------------
# SingleFlight
# ---------------------------------------------------------------------------

def test_singleflight_coalesces_concurrent_same_key():
    async def run():
        sf = SingleFlight()
        calls = []

        async def slow():
            calls.append(1)
            await asyncio.sleep(0.05)
            return b"payload"

        outs = await asyncio.gather(*(sf.run("k", slow) for _ in range(8)))
        assert all(o == b"payload" for o in outs)
        assert len(calls) == 1 and sf.leaders == 1 and sf.joined == 7
        assert sf.inflight == 0
    asyncio.run(run())


def test_singleflight_distinct_keys_run_independently():
    async def run():
        sf = SingleFlight()

        async def make(v):
            await asyncio.sleep(0.01)
            return v

        outs = await asyncio.gather(*(sf.run(i, lambda v=i: make(v))
                                      for i in range(4)))
        assert outs == [0, 1, 2, 3] and sf.leaders == 4 and sf.joined == 0
    asyncio.run(run())


def test_singleflight_leader_error_propagates_to_joiners():
    async def run():
        sf = SingleFlight()

        async def boom():
            await asyncio.sleep(0.02)
            raise ValueError("decode failed")

        results = await asyncio.gather(*(sf.run("k", boom) for _ in range(3)),
                                       return_exceptions=True)
        assert all(isinstance(r, ValueError) for r in results)
        assert sf.leaders == 1 and sf.inflight == 0
    asyncio.run(run())


# ---------------------------------------------------------------------------
# RetrievalEngine
# ---------------------------------------------------------------------------

def test_engine_file_and_tensor_bit_exact(served_store):
    store, originals = served_store

    async def run():
        engine = RetrievalEngine(store, max_concurrency=4)
        try:
            for rid, orig in originals.items():
                assert await engine.get_file(rid) == orig
            # tensor-granular retrieval matches the source mmap bytes
            src = st.SafetensorsFile(
                os.path.join(os.path.dirname(store.root), "hub", "u0", "ft",
                             "model.safetensors"))
            try:
                for ti in src.infos:
                    data, meta = await engine.get_tensor("u0/ft", ti.name)
                    assert data == bytes(src.tensor_bytes(ti.name))
                    assert meta["dtype"] == ti.dtype_str
                    assert tuple(meta["shape"]) == ti.shape
            finally:
                src.close()
        finally:
            await engine.aclose()
    asyncio.run(run())


def test_engine_singleflights_concurrent_decodes(served_store):
    store, originals = served_store

    async def run():
        engine = RetrievalEngine(store, max_concurrency=8)
        try:
            outs = await asyncio.gather(*(engine.get_file("org/base")
                                          for _ in range(8)))
            assert all(o == originals["org/base"] for o in outs)
            stats = engine.stats()
            # one decode, 7 joiners (nothing was cached before the burst)
            assert stats["singleflight"]["leaders"] == 1
            assert stats["singleflight"]["joined"] == 7
            # a second wave hits the response cache, no new flight
            assert await engine.get_file("org/base") == originals["org/base"]
            assert engine.stats()["response_cache"]["hits"] >= 1
        finally:
            await engine.aclose()
    asyncio.run(run())


# ---------------------------------------------------------------------------
# HTTP server
# ---------------------------------------------------------------------------

def test_server_http_endpoints(served_store):
    store, originals = served_store
    with ServerThread(store, max_concurrency=8) as srv:
        status, _, body = _http_get(srv.host, srv.port, "/healthz")
        assert status == 200 and json.loads(body)["ok"] is True

        status, headers, body = _http_get(srv.host, srv.port,
                                          "/repo/org/base/file/model.safetensors")
        assert status == 200
        assert body == originals["org/base"]
        assert headers["x-content-sha256"] == hashlib.sha256(body).hexdigest()

        status, headers, body = _http_get(srv.host, srv.port,
                                          "/repo/u0/ft/tensor/model.t0.weight")
        assert status == 200
        assert headers["x-tensor-dtype"] == "F32"
        # unambiguous query form returns the same bytes
        status2, _, body2 = _http_get(srv.host, srv.port,
                                      "/repo/u0/ft/tensor?name=model.t0.weight")
        assert status2 == 200 and body2 == body
        src = st.SafetensorsFile(os.path.join(
            os.path.dirname(store.root), "hub", "u0", "ft", "model.safetensors"))
        try:
            assert body == bytes(src.tensor_bytes("model.t0.weight"))
        finally:
            src.close()

        status, _, body = _http_get(srv.host, srv.port, "/stats")
        assert status == 200
        stats = json.loads(body)
        assert stats["server"]["requests"] >= 2 and "lifecycle" in stats["store"]

        with pytest.raises(urllib.error.HTTPError) as ei:
            _http_get(srv.host, srv.port, "/repo/no/such/file/model.safetensors")
        assert ei.value.code == 404


def test_server_8_concurrent_retrievals_byte_identical_during_gc(served_store):
    """THE serving acceptance test: 8 concurrent clients hammer the server
    while a gc() (with something real to reclaim) runs mid-flight; every
    response is byte-identical to the direct store read and gc completes."""
    store, originals = served_store
    store.delete_repo("org/victim")         # make the sweep non-trivial
    survivors = [r for r in originals if r != "org/victim"]

    with ServerThread(store, max_concurrency=8) as srv:
        errors = []
        mismatches = []
        start = threading.Barrier(9)        # 8 clients + the gc thread
        gc_result = {}

        def client(cid):
            try:
                start.wait(timeout=30)
                for round_ in range(4):
                    for rid in survivors:
                        _, _, body = _http_get(
                            srv.host, srv.port, f"/repo/{rid}/file/model.safetensors")
                        if body != originals[rid]:
                            mismatches.append((cid, round_, rid))
            except Exception as e:  # pragma: no cover - failure reporting
                errors.append((cid, repr(e)))

        def run_gc():
            start.wait(timeout=30)
            gc_result.update(store.gc())

        threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
        threads.append(threading.Thread(target=run_gc))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert not mismatches, mismatches
        assert gc_result.get("collected", 0) >= 1  # the victim was reclaimed

    # post-gc: victim is gone (404), survivors still serve
    with ServerThread(store, max_concurrency=2) as srv:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _http_get(srv.host, srv.port, "/repo/org/victim/file/model.safetensors")
        assert ei.value.code == 404
        _, _, body = _http_get(srv.host, srv.port,
                               "/repo/org/base/file/model.safetensors")
        assert body == originals["org/base"]


def test_8_clients_byte_identical_during_compact_and_incremental_gc(
        served_store, tmp_path):
    """Satellite acceptance: 8 concurrent HTTP clients read while compact()
    AND an incremental gc() run via the admin endpoints; every response is
    byte-identical to the direct store read, compaction genuinely retires a
    superseded generation mid-serve, and the max exclusive read-gate hold
    stays under the configured pause bound."""
    store, originals = served_store
    # superseded-but-pinned generation: re-register the family base — the
    # fine-tunes keep BitX-pinning base@g0 (skip case) — plus a dedup chain
    # on org/other so compact has real moves+retires, plus plain garbage
    v2 = str(tmp_path / "v2" / "model.safetensors")
    _write_model(v2, np.random.RandomState(55), scale=1.0)
    store.ingest_file(v2, "org/base")
    other = {f"model.t{i}.weight": np.random.RandomState(60 + i).randn(
        2048).astype(np.float32) for i in range(5)}
    for r in range(2):  # partial re-registers -> dedup chain on org/other
        for i in range(5):
            if i % 2 == r:
                other[f"model.t{i}.weight"] = np.random.RandomState(
                    70 + 10 * r + i).randn(2048).astype(np.float32)
        p = str(tmp_path / f"o{r}" / "model.safetensors")
        os.makedirs(os.path.dirname(p), exist_ok=True)
        st.save_file(other, p)
        store.ingest_file(p, "org/other")
    store.delete_repo("org/victim")  # garbage for the incremental sweep
    # post-churn snapshot: what every client must see, byte for byte
    expected = {rid: store.retrieve_file(rid, "model.safetensors")
                for rid in originals if rid != "org/victim"}
    superseded_before = store.summary()["lifecycle"]["superseded_bytes"]
    assert superseded_before > 0

    pause_bound_ms = 1000.0
    with ServerThread(store, max_concurrency=8) as srv:
        errors, mismatches = [], []
        start = threading.Barrier(9)  # 8 clients + the admin thread
        admin: dict = {}

        def client(cid):
            try:
                start.wait(timeout=30)
                for round_ in range(4):
                    for rid in expected:
                        _, _, body = _http_get(
                            srv.host, srv.port,
                            f"/repo/{rid}/file/model.safetensors")
                        if body != expected[rid]:
                            mismatches.append((cid, round_, rid))
            except Exception as e:  # pragma: no cover - failure reporting
                errors.append((cid, repr(e)))

        def run_admin():
            try:
                start.wait(timeout=30)
                _, _, body = _http_get(srv.host, srv.port, "/admin/compact")
                admin["compact"] = json.loads(body)
                _, _, body = _http_get(
                    srv.host, srv.port,
                    f"/admin/gc?incremental=1&max_pause_ms={pause_bound_ms}")
                admin["gc"] = json.loads(body)
            except Exception as e:  # pragma: no cover - failure reporting
                errors.append(("admin", repr(e)))

        threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
        threads.append(threading.Thread(target=run_admin))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert not mismatches, mismatches

    # compaction really happened mid-serve...
    assert admin["compact"]["retired_versions"] >= 1
    assert admin["compact"]["moved_records"] >= 1
    assert admin["gc"]["steps"] >= 1
    # ...and every exclusive hold respected the configured bound
    assert admin["compact"]["exclusive_hold_ms"] < pause_bound_ms
    assert admin["gc"]["max_pause_ms"] < pause_bound_ms
    assert store.stats.gc_max_pause_ms < pause_bound_ms

    # direct post-churn reads agree with what was served, and the store is
    # clean (all post-compact pins validated)
    for rid, data in expected.items():
        assert store.retrieve_file(rid, "model.safetensors") == data
    assert store.fsck(spot_check=None).ok
    assert store.summary()["lifecycle"]["superseded_bytes"] < superseded_before


def test_reregistration_during_serving_rolls_caches_over(served_store, tmp_path):
    """read_gen snapshot keys: after re-registering a key mid-serve, the
    next request must see the NEW bytes, never a stale cached decode."""
    store, originals = served_store
    with ServerThread(store, max_concurrency=4) as srv:
        _, _, body = _http_get(srv.host, srv.port,
                               "/repo/org/other/file/model.safetensors")
        assert body == originals["org/other"]

        v2_path = str(tmp_path / "v2" / "model.safetensors")
        _write_model(v2_path, np.random.RandomState(123), scale=1.0)
        store.ingest_file(v2_path, "org/other")     # ingest while serving
        v2 = open(v2_path, "rb").read()

        _, headers, body = _http_get(srv.host, srv.port,
                                     "/repo/org/other/file/model.safetensors")
        assert body == v2 and body != originals["org/other"]
        # and the old generation is still pinned for old dependants until gc
        assert int(headers["x-read-gen"]) == store.read_gen
