"""Crash-injection harness for compact() and incremental gc().

The store's crash-consistency story is *ordering*, not handlers: container
bytes land via temp-suffix + atomic rename, the index is persisted before
retired files are unlinked, and no cleanup runs when the fault hook raises
— so killing the process at ANY fault point leaves the disk in one of
exactly three shapes:

* the old state, possibly plus an orphan compact container or ``.part``
  temp (debris ``fsck(repair=True)`` deletes);
* the new state, possibly plus orphan retired containers (same);
* the new state, clean.

In every shape, every live file must reopen bit-identical and
``fsck(repair=True)`` must restore all invariants. This suite kills
compact()/gc() at each declared fault point (``store.fault_hook``), reopens
the store from disk like a restarted process, and proves exactly that.
"""

import os

import numpy as np
import pytest

from repro.core.pipeline import (COMPACT_FAULT_POINTS, COMPACT_KEY,
                                 GC_FAULT_POINTS, ZLLMStore)
from repro.formats import safetensors as st

N_TENSORS = 6
N_ELEMS = 256


def _write(path, tensors):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path)


def _fresh(seed, n_tensors=N_TENSORS):
    rng = np.random.RandomState(seed)
    return {f"t{i}": rng.randn(N_ELEMS).astype(np.float32)
            for i in range(n_tensors)}


class _Kill(BaseException):
    """Raised by the fault hook; BaseException so no except-Exception
    handler on the way out can soften the simulated crash."""


def _build_victim(root):
    """On-disk store with everything a compact/gc crash needs to bite: a
    dedup chain (superseded generations pinned by later ones => compact
    moves records AND retires generations, so every fault point fires),
    plain garbage (deleted repo, never gc'd) and an untouched keeper.
    Built fresh per test — the index pins absolute container paths, so a
    copied store root would still point at the original's files. Returns
    the oracle of live-file bytes."""
    store = ZLLMStore(os.path.join(root, "store"))
    cur = _fresh(0)
    p = os.path.join(root, "hub", "g0", "model.safetensors")
    _write(p, cur)
    store.ingest_file(p, "org/b")
    for r in range(3):
        for i in range(N_TENSORS):
            if i % 3 == r:
                cur[f"t{i}"] = np.random.RandomState(500 + 10 * r + i).randn(
                    N_ELEMS).astype(np.float32)
        p = os.path.join(root, "hub", f"g{r + 1}", "model.safetensors")
        _write(p, dict(cur))
        assert store.ingest_file(p, "org/b").n_dedup > 0
    keep = os.path.join(root, "hub", "keep", "model.safetensors")
    _write(keep, _fresh(42))
    store.ingest_file(keep, "org/keep")
    dead = os.path.join(root, "hub", "dead", "model.safetensors")
    _write(dead, _fresh(43))
    store.ingest_file(dead, "org/dead")
    store.delete_repo("org/dead")  # garbage for the gc sweeps
    store.save_index()
    oracle = {rid: store.retrieve_file(rid, "model.safetensors")
              for rid in ("org/b", "org/keep")}
    store.close()
    return oracle


def _crash_store(root):
    store = ZLLMStore(os.path.join(root, "store"))
    assert store.load_index()
    return store


def _verify_recovered(root, oracle):
    """Reopen like a restarted process: repair must restore every
    invariant, delete all crash debris, and lose no live tensor."""
    with ZLLMStore(os.path.join(root, "store")) as s:
        assert s.load_index()
        s.fsck(repair=True, spot_check=None)
        report = s.fsck(repair=False, spot_check=None)
        assert report.ok, (report.dangling, report.corrupt)
        assert not report.orphans, report.orphans
        for rid, data in oracle.items():
            assert s.retrieve_file(rid, "model.safetensors") == data, \
                f"live tensor data lost for {rid}"
        # the recovered store is fully operational: churn + compact work
        s.compact()
        for rid, data in oracle.items():
            assert s.retrieve_file(rid, "model.safetensors") == data
        assert s.fsck(spot_check=None).ok


@pytest.mark.parametrize("point", COMPACT_FAULT_POINTS)
def test_compact_killed_at_every_fault_point(point, tmp_path):
    root = str(tmp_path)
    oracle = _build_victim(root)
    store = _crash_store(root)
    fired = []

    def hook(p):
        if p == point:
            fired.append(p)
            raise _Kill(p)

    store.fault_hook = hook
    with pytest.raises(_Kill):
        store.compact()
    assert fired == [point], f"fault point {point} never fired"
    store.fault_hook = None
    store.close()  # drop fds; the disk state stays exactly as the kill left it
    if point == "writer.after_temp":  # the half-written compact output exists
        assert os.path.exists(store._container_path(COMPACT_KEY, 0) + ".part")
    _verify_recovered(root, oracle)


@pytest.mark.parametrize("point", GC_FAULT_POINTS)
def test_incremental_gc_killed_at_every_fault_point(point, tmp_path):
    root = str(tmp_path)
    oracle = _build_victim(root)
    store = _crash_store(root)
    fired = []

    def hook(p):
        if p == point:
            fired.append(p)
            raise _Kill(p)

    store.fault_hook = hook
    with pytest.raises(_Kill):
        store.gc(incremental=True, max_pause_ms=0.0)
    assert fired[:1] == [point], f"fault point {point} never fired"
    store.fault_hook = None
    store.close()
    _verify_recovered(root, oracle)


def test_compact_crash_then_resume_completes_the_job(tmp_path):
    """After a mid-compact kill and repair, a rerun of compact() finishes
    the reclamation the crashed run started."""
    root = str(tmp_path)
    oracle = _build_victim(root)
    store = _crash_store(root)

    def hook(p):
        if p == "compact.after_commit":
            raise _Kill(p)

    store.fault_hook = hook
    with pytest.raises(_Kill):
        store.compact()
    store.close()

    with ZLLMStore(os.path.join(root, "store")) as s:
        assert s.load_index()
        s.fsck(repair=True, spot_check=None)
        rep = s.compact()
        assert rep["retired_versions"] > 0  # the job completes post-crash
        rep2 = s.compact()
        assert rep2["retired_versions"] == 0  # and converges
        for rid, data in oracle.items():
            assert s.retrieve_file(rid, "model.safetensors") == data
        assert s.fsck(spot_check=None).ok
