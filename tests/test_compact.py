"""Dedup-aware ``compact()`` + incremental ``gc()``.

Covers the compaction tentpole: rewriting still-referenced tensor records
(payloads, dedup targets, BitX bases) out of superseded generations into
fresh ``.compact/pool`` containers, atomic re-pinning, retirement of the
old generations, idempotence, index-v3 persistence (with v2 back-compat),
the bounded-pause incremental GC with its resumable cursor — and a
property-based churn harness that interleaves
ingest/re-register/delete/gc/compact randomly and holds every live file
byte-identical to a shadow dict-of-bytes oracle throughout.
"""

import json
import os
import shutil
import tempfile

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as stt
from repro.core.lifecycle import make_vid
from repro.core.pipeline import COMPACT_KEY, ZLLMStore
from repro.formats import safetensors as st

N_TENSORS = 6
N_ELEMS = 512


def _write(path, tensors):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path)


def _fresh_tensors(seed, n_tensors=N_TENSORS, n=N_ELEMS):
    rng = np.random.RandomState(seed)
    return {f"t{i}": rng.randn(n).astype(np.float32) for i in range(n_tensors)}


def _read(path):
    with open(path, "rb") as f:
        return f.read()


def _chain_store(tmp_path, rounds=3):
    """Base ingested, then ``rounds`` partial re-registers that each replace
    a rotating third of the tensors with fresh random content (large bit
    distance, so the new generations store standalone and *dedup* the
    unchanged tensors against pins in older generations — the chain that
    leaves dead payloads gc cannot reclaim). Returns
    (store, final file bytes, per-gen paths)."""
    store = ZLLMStore(str(tmp_path / "store"))
    cur = _fresh_tensors(0)
    paths = []
    p = str(tmp_path / "hub" / "g0" / "model.safetensors")
    _write(p, cur)
    paths.append(p)
    store.ingest_file(p, "org/b")
    for r in range(rounds):
        for i in range(N_TENSORS):
            if i % rounds == r:
                cur[f"t{i}"] = np.random.RandomState(1000 + 10 * r + i).randn(
                    N_ELEMS).astype(np.float32)
        p = str(tmp_path / "hub" / f"g{r + 1}" / "model.safetensors")
        _write(p, dict(cur))
        res = store.ingest_file(p, "org/b")
        assert res.n_dedup > 0, "setup: chain must dedup unchanged tensors"
        paths.append(p)
    assert store.file_index["org/b/model.safetensors"]["gen"] == rounds
    return store, _read(paths[-1]), paths


# ---------------------------------------------------------------------------
# compact(): reclaim, re-pin, bit-identity, idempotence
# ---------------------------------------------------------------------------

def test_compact_reclaims_dedup_chain_and_preserves_bytes(tmp_path):
    """THE acceptance scenario: after a re-register chain, the superseded
    generations are pinned by later generations' dedup records but mostly
    dead. compact() must move exactly the still-referenced payloads into a
    fresh container, retire every superseded generation, reclaim >= 30% of
    the superseded bytes net, and keep the live file bit-identical."""
    store, final, _ = _chain_store(tmp_path)
    assert store.gc()["collected"] == 0  # the chain pins everything
    superseded = store.summary()["lifecycle"]["superseded_bytes"]
    assert superseded > 0

    rep = store.compact()
    assert rep["retired_versions"] == rep["superseded_versions"] == 3
    assert rep["moved_records"] > 0
    assert rep["container"] == make_vid(COMPACT_KEY, 0)
    assert rep["reclaimed_bytes"] == superseded
    assert rep["net_reclaimed_bytes"] >= 0.3 * superseded  # the ISSUE bar
    assert store.stats.compaction_reclaimed_bytes == rep["net_reclaimed_bytes"]
    assert store.stats.compact_runs == 1

    # moved hashes now pin into the compact pool, old gens are gone
    pool_pins = [loc for loc in store.tensor_locations.values()
                 if loc[0] == COMPACT_KEY]
    assert len(pool_pins) == rep["moved_records"]
    for g in range(3):
        assert not store.lifecycle.exists("org/b/model.safetensors", g)
        assert not os.path.exists(
            store._container_path("org/b/model.safetensors", g))

    # equivalence proof: the live file decodes bit-identically through the
    # pool, and fsck validates every post-compact pin
    assert store.retrieve_file("org/b", "model.safetensors") == final
    assert store.fsck(spot_check=None).ok
    store.close()


def test_compact_is_idempotent_on_its_own_pool(tmp_path):
    """A second compact() must not rewrite the pool it just wrote: the pool
    container is pure payload and fully needed, so it is skipped."""
    store, final, _ = _chain_store(tmp_path)
    store.compact()
    rep2 = store.compact()
    assert rep2["moved_records"] == 0 and rep2["retired_versions"] == 0
    assert rep2["skipped_versions"] == 1  # the pool itself
    assert store.lifecycle.exists(COMPACT_KEY, 0)
    assert store.retrieve_file("org/b", "model.safetensors") == final
    assert store.fsck(spot_check=None).ok
    store.close()


def test_compact_skips_fully_needed_base_generation(tmp_path):
    """A superseded base whose EVERY payload is still a live fine-tune's
    BitX base is pure relocation — compact must leave it in place (zero
    churn), and the fine-tune keeps decoding against it."""
    base = _fresh_tensors(1)
    bp = str(tmp_path / "hub" / "b" / "model.safetensors")
    fp = str(tmp_path / "hub" / "f" / "model.safetensors")
    _write(bp, base)
    rng = np.random.RandomState(2)
    _write(fp, {k: v + rng.randn(*v.shape).astype(np.float32) * 1e-3
                for k, v in base.items()})
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_file(bp, "org/b")
    res = store.ingest_file(fp, "u/f", declared_base="org/b/model.safetensors")
    assert res.n_bitx == N_TENSORS
    # supersede the base with unrelated content (standalone)
    v2 = str(tmp_path / "hub" / "v2" / "model.safetensors")
    _write(v2, _fresh_tensors(99))
    store.ingest_file(v2, "org/b")

    rep = store.compact()
    assert rep["superseded_versions"] == 1
    assert rep["skipped_versions"] == 1 and rep["retired_versions"] == 0
    assert rep["moved_records"] == 0 and rep["container"] is None
    assert store.lifecycle.exists("org/b/model.safetensors", 0)
    assert store.retrieve_file("u/f", "model.safetensors") == _read(fp)
    assert store.fsck(spot_check=None).ok
    store.close()


def test_compact_moves_bitx_bases_of_live_finetunes(tmp_path):
    """A superseded base that is only PARTIALLY referenced (the fine-tune
    covers a subset of its tensors) must be compacted: the referenced base
    payloads move into the pool, the generation retires, and the
    fine-tune's BitX records decode through the pool bit-identically."""
    base = _fresh_tensors(3, n_tensors=6)
    bp = str(tmp_path / "hub" / "b" / "model.safetensors")
    _write(bp, base)
    # fine-tune only carries 3 of the 6 base tensors
    rng = np.random.RandomState(4)
    ft = {k: base[k] + rng.randn(N_ELEMS).astype(np.float32) * 1e-3
          for k in ("t0", "t1", "t2")}
    fp = str(tmp_path / "hub" / "f" / "model.safetensors")
    _write(fp, ft)
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_file(bp, "org/b")
    res = store.ingest_file(fp, "u/f", declared_base="org/b/model.safetensors")
    assert res.n_bitx == 3
    v2 = str(tmp_path / "hub" / "v2" / "model.safetensors")
    _write(v2, _fresh_tensors(77))
    store.ingest_file(v2, "org/b")

    rep = store.compact()
    assert rep["retired_versions"] == 1 and rep["moved_records"] == 3
    assert not store.lifecycle.exists("org/b/model.safetensors", 0)
    # the moved records are the fine-tune's bases, pinned into the pool
    for k in ("t0", "t1", "t2"):
        # resolve via decode: bit-identical through the pool
        data, meta = store.retrieve_tensor("u/f", "model.safetensors", k)
        assert data == ft[k].tobytes() and meta["codec"] == "bitx"
    assert store.retrieve_file("u/f", "model.safetensors") == _read(fp)
    assert store.fsck(spot_check=None).ok
    store.close()


def test_compact_noop_when_nothing_superseded(tmp_path):
    p = str(tmp_path / "hub" / "m" / "model.safetensors")
    _write(p, _fresh_tensors(5))
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_file(p, "org/m")
    rep = store.compact()
    assert rep == {**rep, "superseded_versions": 0, "moved_records": 0,
                   "retired_versions": 0, "container": None}
    assert store.stats.compact_runs == 0  # a no-op is not a run
    assert store.retrieve_file("org/m", "model.safetensors") == _read(p)
    store.close()


def test_compact_retires_unreachable_garbage_without_container(tmp_path):
    """Unreachable versions (deleted, never gc'd) are retired by compact
    directly — no pool container is written for them."""
    p = str(tmp_path / "hub" / "m" / "model.safetensors")
    _write(p, _fresh_tensors(6))
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_file(p, "org/m")
    cpath = store.file_index["org/m/model.safetensors"]["path"]
    store.delete_repo("org/m")
    rep = store.compact()
    assert rep["retired_versions"] == 1 and rep["container"] is None
    assert not os.path.exists(cpath)
    assert store.lifecycle.versions == {}
    assert store.fsck(spot_check=None).ok
    store.close()


def test_compact_never_touches_file_dedup_anchored_generations(tmp_path):
    """A whole-file-dedup alias pins the generation serving its bytes; that
    generation is an anchor, so compact must neither move nor retire it
    even after the original key is re-registered."""
    p = str(tmp_path / "hub" / "m" / "model.safetensors")
    _write(p, _fresh_tensors(7))
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_file(p, "org/m")
    cp = str(tmp_path / "hub" / "copy" / "model.safetensors")
    os.makedirs(os.path.dirname(cp), exist_ok=True)
    shutil.copyfile(p, cp)
    assert store.ingest_file(cp, "mirror/m").file_dedup_hit
    v2 = str(tmp_path / "hub" / "v2" / "model.safetensors")
    _write(v2, _fresh_tensors(88))
    store.ingest_file(v2, "org/m")  # original superseded at the key level

    rep = store.compact()
    assert rep["superseded_versions"] == 0  # alias anchors gen 0
    assert store.lifecycle.exists("org/m/model.safetensors", 0)
    assert store.retrieve_file("mirror/m", "model.safetensors") == _read(p)
    assert store.retrieve_file("org/m", "model.safetensors") == _read(v2)
    assert store.fsck(spot_check=None).ok
    store.close()


def test_compact_pool_collected_when_last_dependant_dies(tmp_path):
    """The pool container is an ordinary version: once nothing references
    its records, gc reclaims it (and scrubs its pins)."""
    store, final, _ = _chain_store(tmp_path)
    store.compact()
    assert store.lifecycle.exists(COMPACT_KEY, 0)
    store.delete_repo("org/b")
    swept = store.gc()
    assert swept["collected"] == 2  # the live gen + the pool
    assert not store.lifecycle.exists(COMPACT_KEY, 0)
    assert not any(k == COMPACT_KEY for k, _, _ in store.tensor_locations.values())
    assert store.fsck(spot_check=None).ok
    store.close()


def test_compact_survives_index_roundtrip(tmp_path):
    """compact() persists the index itself (persist-then-unlink): a fresh
    process loads the post-compact state and serves bit-identically."""
    store, final, _ = _chain_store(tmp_path)
    store.compact()  # persist=True by default
    store.close()
    with ZLLMStore(str(tmp_path / "store")) as s2:
        assert s2.load_index()
        assert s2.lifecycle.exists(COMPACT_KEY, 0)
        assert s2.stats.compact_runs == 1
        assert s2.retrieve_file("org/b", "model.safetensors") == final
        assert s2.fsck(spot_check=None).ok


# ---------------------------------------------------------------------------
# incremental gc: bounded steps, resumable cursor, index v3
# ---------------------------------------------------------------------------

def _garbage_store(tmp_path, n=5):
    store = ZLLMStore(str(tmp_path / "store"))
    for i in range(n):
        p = str(tmp_path / "hub" / f"m{i}" / "model.safetensors")
        _write(p, _fresh_tensors(100 + i, n_tensors=2, n=128))
        store.ingest_file(p, f"org/m{i}")
    keep = str(tmp_path / "hub" / "keep" / "model.safetensors")
    _write(keep, _fresh_tensors(999, n_tensors=2, n=128))
    store.ingest_file(keep, "org/keep")
    for i in range(n):
        store.delete_repo(f"org/m{i}")
    return store, keep


def test_incremental_gc_matches_full_sweep(tmp_path):
    """With a near-zero pause budget every step retires exactly one
    version; the aggregate must equal what a stop-the-world sweep would
    reclaim, the pause metric must be recorded, and survivors stay
    bit-exact."""
    store, keep = _garbage_store(tmp_path, n=5)
    agg = store.gc(incremental=True, max_pause_ms=0.0, persist=False)
    assert agg["collected"] == 5
    assert agg["steps"] >= 5  # one victim per zero-budget step (+ final empty)
    assert agg["max_pause_ms"] > 0
    assert store.stats.gc_max_pause_ms >= agg["max_pause_ms"]
    assert store._gc_cursor == ""  # completed sweep resets the cursor
    assert store.gc()["collected"] == 0  # nothing left for stop-the-world
    assert store.retrieve_file("org/keep", "model.safetensors") == _read(keep)
    assert store.fsck(spot_check=None).ok
    store.close()


def test_incremental_gc_cursor_resumes_across_reload(tmp_path):
    """A single bounded step persists its cursor in the v3 index; a fresh
    process resumes the sweep where the last one stopped."""
    store, keep = _garbage_store(tmp_path, n=4)
    step = store.gc_step(max_pause_ms=0.0, persist=True)
    assert step["collected"] == 1 and step["remaining"] == 3
    cursor = store._gc_cursor
    assert cursor
    store.close()

    with ZLLMStore(str(tmp_path / "store")) as s2:
        assert s2.load_index()
        assert s2._gc_cursor == cursor
        agg = s2.gc(incremental=True, max_pause_ms=1000.0)
        assert agg["collected"] == 3
        assert s2._gc_cursor == ""
        assert s2.retrieve_file("org/keep", "model.safetensors") == _read(keep)
        assert s2.fsck(spot_check=None).ok


def test_incremental_gc_interleaves_with_ingest(tmp_path):
    """The admin lock is released between steps: an ingest issued after a
    step (here: sequentially, between manual steps) lands normally and the
    next step's re-mark sees it as an anchor."""
    store, keep = _garbage_store(tmp_path, n=3)
    assert store.gc_step(max_pause_ms=0.0, persist=False)["collected"] == 1
    mid = str(tmp_path / "hub" / "mid" / "model.safetensors")
    _write(mid, _fresh_tensors(555, n_tensors=2, n=128))
    store.ingest_file(mid, "org/mid")  # between steps
    while not store.gc_step(max_pause_ms=0.0, persist=False)["done"]:
        pass
    assert store.retrieve_file("org/mid", "model.safetensors") == _read(mid)
    assert store.retrieve_file("org/keep", "model.safetensors") == _read(keep)
    assert store.fsck(spot_check=None).ok
    store.close()


def test_index_v2_backward_compat_load(tmp_path):
    """A v2 index (PR-2/3 era: no gc_cursor, no compaction stats) must load
    with the new fields defaulted and churn working immediately."""
    store, final, _ = _chain_store(tmp_path)
    idx_path = store.save_index()
    store.close()

    idx = json.load(open(idx_path))
    assert idx["format"] == 4
    idx["format"] = 2
    del idx["gc_cursor"]
    idx["lifecycle"].pop("tombstones", None)  # v4-only key
    for k in ("compaction_reclaimed_bytes", "compact_runs", "gc_max_pause_ms",
              "auto_compact_runs"):
        idx["stats"].pop(k, None)
    with open(idx_path, "w") as f:
        json.dump(idx, f)

    with ZLLMStore(str(tmp_path / "store")) as s2:
        assert s2.load_index()
        assert s2._gc_cursor == "" and s2.stats.compact_runs == 0
        assert s2.retrieve_file("org/b", "model.safetensors") == final
        rep = s2.compact()  # compaction works on the upgraded store
        assert rep["retired_versions"] == 3
        assert s2.retrieve_file("org/b", "model.safetensors") == final
        assert s2.fsck(spot_check=None).ok


# ---------------------------------------------------------------------------
# Property-based churn: random interleavings vs a shadow oracle
# ---------------------------------------------------------------------------

_P_TENSORS = 3
_P_ELEMS = 64


def _churn(ops, root):
    """Drive one random churn sequence. The oracle is a dict of raw file
    bytes per live repo; every operation must keep each live file
    retrieving byte-identically, and the store must finish fsck-clean and
    reload-clean."""
    rids = ["r0", "r1", "r2", "r3"]
    store = ZLLMStore(os.path.join(root, "store"))
    oracle = {}
    content = {}
    seq = 0
    try:
        for op in ops:
            rid = rids[op % len(rids)]
            kind = (op // len(rids)) % 6
            if kind == 0 or (kind == 1 and rid not in content):
                # fresh ingest (new random content)
                tensors = {f"t{i}": np.random.RandomState(op * 7 + i).randn(
                    _P_ELEMS).astype(np.float32) for i in range(_P_TENSORS)}
            elif kind == 1:
                # partial re-register: flip a drawn subset of tensors
                tensors = dict(content[rid])
                for i in range(_P_TENSORS):
                    if (op >> (4 + i)) & 1:
                        tensors[f"t{i}"] = np.random.RandomState(
                            op * 13 + i).randn(_P_ELEMS).astype(np.float32)
            elif kind == 2:
                # duplicate upload: another live repo's exact bytes
                src = next((r for r in rids if r in oracle and r != rid), None)
                if src is None:
                    continue
                seq += 1
                p = os.path.join(root, "hub", f"u{seq}", "model.safetensors")
                os.makedirs(os.path.dirname(p), exist_ok=True)
                with open(p, "wb") as f:
                    f.write(oracle[src])
                store.ingest_file(p, rid)
                oracle[rid] = oracle[src]
                content[rid] = dict(content[src])
                continue
            elif kind == 3:
                if rid in oracle:
                    store.delete_repo(rid)
                    del oracle[rid], content[rid]
                continue
            elif kind == 4:
                if op % 2:
                    store.gc()
                else:
                    store.gc(incremental=True, max_pause_ms=0.5, persist=False)
                continue
            else:
                store.compact(persist=False)
                continue
            seq += 1
            p = os.path.join(root, "hub", f"u{seq}", "model.safetensors")
            _write(p, tensors)
            store.ingest_file(p, rid)
            content[rid] = tensors
            oracle[rid] = _read(p)
            # spot-check one live repo after every mutating op
            probe = sorted(oracle)[op % len(oracle)]
            assert store.retrieve_file(probe, "model.safetensors") == oracle[probe]
        # the full invariant: every live file bit-identical, store clean
        for rid, data in oracle.items():
            assert store.retrieve_file(rid, "model.safetensors") == data
        report = store.fsck(spot_check=None)
        assert report.ok, (report.dangling, report.corrupt)
        store.save_index()
    finally:
        store.close()
    with ZLLMStore(os.path.join(root, "store")) as s2:
        assert s2.load_index()
        for rid, data in oracle.items():
            assert s2.retrieve_file(rid, "model.safetensors") == data
        assert s2.fsck(spot_check=None).ok


@settings(deadline=None, max_examples=10)
@given(stt.lists(stt.integers(0, 2 ** 20), min_size=6, max_size=24))
def test_property_random_churn_matches_shadow_oracle(ops):
    root = tempfile.mkdtemp(prefix="zllm-compact-prop-")
    try:
        _churn(ops, root)
    finally:
        shutil.rmtree(root, ignore_errors=True)
