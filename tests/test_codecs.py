"""Codec registry + CodecRuntime contract tests (satellites of the
backend/registry redesign): registration semantics, the loud unknown-codec
failure, the thread-guarded zstd contexts, and the one-release deprecation
shims over the old free functions."""

import threading
import warnings
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import codecs
from repro.core.bitx import BitXCodec, BitXReader, BitXWriter, get_backend
from repro.core.codecs import (CodecRuntime, EncodeInput, get_codec,
                               raw_or_stored, register_codec,
                               registered_codecs)


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------

def test_builtin_codecs_registered():
    assert registered_codecs() == ("bitx", "bitxq", "dedup", "raw", "stored",
                                   "zipnn")


def test_unknown_codec_raises_naming_it():
    with pytest.raises(ValueError) as ei:
        get_codec("huffllm-v2")
    msg = str(ei.value)
    assert "huffllm-v2" in msg
    # the error lists what IS registered, so the operator can tell a typo
    # from a newer-build container
    assert "bitx" in msg and "zipnn" in msg


def test_register_duplicate_rejected_unless_replace():
    enc = lambda rt, inp: ("bitx", [], 0)
    dec = lambda rt, r, frames, d, br, pr: np.empty(0)
    with pytest.raises(ValueError, match="already registered"):
        register_codec("bitx", enc, dec)
    # replace=True is the escape hatch; restore the original right away
    orig = get_codec("bitx")
    try:
        register_codec("bitx", enc, dec, replace=True)
        assert get_codec("bitx").encode is enc
    finally:
        register_codec("bitx", orig.encode, orig.decode, replace=True)


def test_unknown_stamped_codec_on_load_raises(tmp_path):
    """A container stamped with a codec this build doesn't know must fail
    loudly at decode, naming the codec — never silently mis-decode."""
    rng = np.random.default_rng(3)
    x = rng.random((64,), np.float32)
    w = BitXWriter()
    w.add_zipnn("t0", "F32", (64,), x, "sh")
    path = str(tmp_path / "c.bitx")
    w.write(path)
    r = BitXReader.open(path)
    try:
        r.records[0].codec = "from-the-future"
        with pytest.raises(ValueError, match="from-the-future"):
            r.decode_tensor(0, None, None)
    finally:
        r.close()


def test_raw_or_stored_downgrade():
    incompressible = bytes(np.random.default_rng(0).integers(0, 256, 64, np.uint8))
    assert raw_or_stored(incompressible, incompressible + b"x") == ("stored", incompressible)
    assert raw_or_stored(b"a" * 100, b"frame") == ("raw", b"frame")


def test_encode_planes_shortcircuit_matches_full():
    """The device-batched path hands pre-split planes to the codec; frames
    must equal the codec splitting the planes itself."""
    rt = CodecRuntime()
    rng = np.random.default_rng(5)
    x = rng.random((129,), np.float32)
    _, full, raw_full = get_codec("zipnn").encode(rt, EncodeInput(data=x))
    planes = rt.backend.byte_planes(x)
    _, pre, raw_pre = get_codec("zipnn").encode(
        rt, EncodeInput(planes=planes, raw_size=int(x.nbytes)))
    assert full == pre and raw_full == raw_pre == x.nbytes


# ---------------------------------------------------------------------------
# Thread-guarded zstd contexts (the small-fix satellite)
# ---------------------------------------------------------------------------

def test_ctx_used_from_owner_thread_ok():
    rt = CodecRuntime()
    ctx = rt._compressor()
    assert ctx.compress(b"hello" * 100)  # same thread: fine


def test_ctx_smuggled_across_threads_asserts():
    """Grabbing a raw context object on one thread and using it from another
    must trip the owner assertion — the exact bug class the runtime exists
    to prevent (zstd contexts are not thread-safe)."""
    rt = CodecRuntime()
    ctx = rt._compressor()  # materialized on THIS thread
    err: list = []

    def smuggle():
        try:
            ctx.compress(b"x" * 64)
        except BaseException as e:  # AssertionError
            err.append(e)

    t = threading.Thread(target=smuggle)
    t.start()
    t.join()
    assert len(err) == 1 and isinstance(err[0], AssertionError)
    assert "not thread-safe" in str(err[0])


def test_runtime_contexts_are_per_thread():
    """Going through runtime.compress from N threads hands each thread its
    own context (distinct guard objects), and the frames stay identical to
    serial — per-thread contexts never change the bytes."""
    rt = CodecRuntime()
    blob = bytes(np.random.default_rng(1).integers(0, 4, 4096, np.uint8))
    serial = rt.compress(blob)
    guards = {}
    lock = threading.Lock()

    def work(_):
        frame = rt.compress(blob)
        with lock:
            guards[threading.get_ident()] = rt._compressor()
        return frame

    with ThreadPoolExecutor(4) as ex:
        frames = list(ex.map(work, range(16)))
    assert all(f == serial for f in frames)
    assert len(set(id(g) for g in guards.values())) == len(guards) >= 2


# ---------------------------------------------------------------------------
# Deprecation shims + facade
# ---------------------------------------------------------------------------

def test_free_function_shims_warn_and_match_backend():
    from repro.core import bitx
    nb = get_backend("numpy")
    rng = np.random.default_rng(2)
    base = rng.random((33,), np.float32)
    ft = base + rng.random((33,), np.float32) * 1e-3
    with warnings.catch_warnings(record=True) as wl:
        warnings.simplefilter("always")
        planes = bitx.xor_delta_planes_np(base, ft)
        merged = bitx.merge_planes_xor_np(planes, base)
        split = bitx.byte_planes_np(ft)
    assert [w for w in wl if issubclass(w.category, DeprecationWarning)], \
        "shims must emit DeprecationWarning"
    ref = nb.xor_delta_planes(base, ft)
    assert all((a == b).all() for a, b in zip(planes, ref))
    assert (merged == nb.merge_planes_xor(ref, base)).all()
    assert all((a == b).all() for a, b in zip(split, nb.byte_planes(ft)))


def test_bitx_codec_facade_roundtrip():
    """The retained BitXCodec class must keep working through the registry
    for one release (external callers)."""
    rng = np.random.default_rng(7)
    base = rng.random((301,), np.float32)
    ft = base + rng.random((301,), np.float32) * 1e-4
    c = BitXCodec(level=3, threads=2)
    assert c.level == 3 and c.threads == 2
    frames, raw = c.encode_delta(base, ft)
    assert raw == ft.nbytes
    assert (c.decode_delta(frames, base) == ft.view(np.uint32)).all()
    frames, raw = c.encode_planes(ft)
    out = c.decode_planes(frames, np.dtype("<f4"), ft.shape)
    assert out.dtype == np.dtype("<f4") and (out == ft).all()
    data = b"\x00" * 500
    assert c.decode_raw(c.encode_raw(data)) == data
    assert BitXCodec.choose_raw_codec(data, b"tiny") == ("raw", b"tiny")
