"""Backend equivalence sweep: the batched jax/Pallas path (interpret mode on
CPU) must be bit-identical to the numpy host path — per-op across dtypes ×
odd/padded shapes × bucket sizes, and end-to-end at the store level (same
corpus, same bytes on disk). This is the workers-1-vs-4 determinism machinery
extended along the backend axis: since containers are pure functions of
(bytes, level, threads, backend-semantics), proving the array transforms
bit-identical proves the containers are too."""

import os

import numpy as np
import pytest

from repro.core.bitx import JaxBackend, NumpyBackend, get_backend
from repro.core.pipeline import ZLLMStore

pytestmark = pytest.mark.skipif(not JaxBackend.available(),
                                reason="jax not installed")

NP = NumpyBackend()

# dtypes the sweep covers: bf16 rides its u16 bit view (exactly how the
# pipeline stores BF16 tensors), fp32 is the common standalone case, int8
# exercises the kernel-unsupported-kind path (host bit-view conversion
# before launch), fp64 exercises the 8-byte host fallback (jax x64 off).
DTYPES = ["uint16", "float32", "int8", "float64"]

# odd / padded / tiny / multi-dim shapes: 1 element, non-multiples of the
# 1024-lane kernel tiling, one exact multiple, and a 2-D tensor
SHAPES = [(1,), (3,), (37, 5), (1023,), (1024,), (1025,), (4096,)]

BUCKETS = [1, 2, 5]


def _mk(dtype, shape, seed):
    rng = np.random.default_rng(seed)
    if np.dtype(dtype).kind in "ui":
        info = np.iinfo(dtype)
        return rng.integers(info.min, info.max, shape, dtype)
    return rng.random(shape).astype(dtype)


def _assert_plane_lists_equal(a, b):
    assert len(a) == len(b)
    for g1, g2 in zip(a, b):
        assert len(g1) == len(g2)
        for p1, p2 in zip(g1, g2):
            assert p1.dtype == p2.dtype and (p1 == p2).all()


@pytest.fixture(scope="module")
def jx():
    return get_backend("jax")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_single_op_equivalence(jx, dtype, shape):
    x = _mk(dtype, shape, 11)
    base = _mk(dtype, shape, 12)
    # zipnn split/merge
    p_np, p_jx = NP.byte_planes(x), jx.byte_planes(x)
    _assert_plane_lists_equal([p_np], [p_jx])
    m_np = NP.merge_planes(p_np, np.dtype(dtype), shape)
    m_jx = jx.merge_planes(p_np, np.dtype(dtype), shape)
    assert m_np.dtype == m_jx.dtype and m_np.shape == m_jx.shape
    assert (m_np == m_jx).all() and (m_np == x).all()
    # bitx xor/merge
    d_np = NP.xor_delta_planes(base.reshape(-1), x.reshape(-1))
    d_jx = jx.xor_delta_planes(base.reshape(-1), x.reshape(-1))
    _assert_plane_lists_equal([d_np], [d_jx])
    r_np = NP.merge_planes_xor(d_np, base.reshape(-1))
    r_jx = jx.merge_planes_xor(d_np, base.reshape(-1))
    assert r_np.dtype == r_jx.dtype and (r_np == r_jx).all()


@pytest.mark.parametrize("bucket", BUCKETS)
def test_batched_ops_equal_mapped_singles(jx, bucket):
    """One fused launch over a concatenated bucket must slice back to exactly
    the per-tensor results — across mixed dtypes in one batch, so the
    dtype-grouping logic is exercised too."""
    xs, pairs = [], []
    seed = 0
    for dtype in DTYPES:
        for shape in SHAPES[:bucket + 2]:
            seed += 2
            x, b = _mk(dtype, shape, seed), _mk(dtype, shape, seed + 1)
            xs.append(x)
            pairs.append((b.reshape(-1), x.reshape(-1)))
    xs, pairs = xs[: bucket * 4], pairs[: bucket * 4]
    _assert_plane_lists_equal(jx.byte_planes_batch(xs),
                              [NP.byte_planes(x) for x in xs])
    d_batch = jx.xor_delta_planes_batch(pairs)
    d_ref = [NP.xor_delta_planes(b, f) for b, f in pairs]
    _assert_plane_lists_equal(d_batch, d_ref)
    m_batch = jx.merge_planes_xor_batch([(d, b) for d, (b, _) in zip(d_ref, pairs)])
    m_ref = [NP.merge_planes_xor(d, b) for d, (b, _) in zip(d_ref, pairs)]
    for a, b in zip(m_batch, m_ref):
        assert a.dtype == b.dtype and (a == b).all()
    z_items = [(NP.byte_planes(x), x.dtype, x.shape) for x in xs]
    z_batch = jx.merge_planes_batch(z_items)
    for got, x in zip(z_batch, xs):
        assert got.dtype == x.dtype and got.shape == x.shape and (got == x).all()


def test_roundtrip_through_jax_recovers_exact_bits(jx):
    """Full encode→decode on the jax path alone is the identity on bits."""
    for dtype in DTYPES:
        x = _mk(dtype, (777,), 31)
        base = _mk(dtype, (777,), 32)
        planes = jx.xor_delta_planes(base, x)
        back = jx.merge_planes_xor(planes, base)
        assert bytes(back.tobytes()) == x.tobytes()
        split = jx.byte_planes(x)
        merged = jx.merge_planes(split, np.dtype(dtype), (777,))
        assert merged.tobytes() == x.tobytes()


# ---------------------------------------------------------------------------
# Store level: same corpus, same bytes on disk
# ---------------------------------------------------------------------------

def _container_bytes(store_root):
    out = {}
    croot = os.path.join(store_root, "containers")
    for dirpath, _, files in os.walk(croot):
        for fn in files:
            p = os.path.join(dirpath, fn)
            out[os.path.relpath(p, croot)] = open(p, "rb").read()
    return out


def test_store_containers_bit_identical_numpy_vs_jax(tmp_path, corpus_dir):
    """The acceptance-criterion test: ``backend="jax"`` (batched device
    encode, parallel workers) writes byte-identical containers to
    ``backend="numpy"`` (serial reference) over the shared corpus, and both
    retrieve bit-exactly."""
    root, manifest = corpus_dir
    stores = {}
    for name, kw in (("numpy", dict(workers=0, backend="numpy")),
                     ("jax", dict(workers=4, backend="jax"))):
        s = ZLLMStore(str(tmp_path / name), **kw)
        for rid, kind in manifest:
            s.ingest_repo(os.path.join(root, rid), rid)
        stores[name] = s
    assert stores["numpy"].summary()["array_backend"] == "numpy"
    assert stores["jax"].summary()["array_backend"] == "jax"

    c_np = _container_bytes(str(tmp_path / "numpy"))
    c_jx = _container_bytes(str(tmp_path / "jax"))
    assert c_np.keys() == c_jx.keys() and len(c_np) > 0
    for name in c_np:
        assert c_np[name] == c_jx[name], f"container diverged across backends: {name}"

    for rid, kind in manifest:
        orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
        assert stores["jax"].retrieve_file(rid, "model.safetensors") == orig
    for s in stores.values():
        s.close()


def test_get_backend_resolution():
    assert get_backend("numpy").name == "numpy"
    assert get_backend("jax").name == "jax"
    # auto on a CPU-only box falls back to numpy (throughput: interpret-mode
    # kernels are Python emulation); on an accelerator host it picks jax
    import jax
    expected = "numpy" if jax.default_backend() == "cpu" else "jax"
    assert get_backend("auto").name == expected
    # instances pass through, unknown names fail loudly
    nb = NumpyBackend()
    assert get_backend(nb) is nb
    with pytest.raises(ValueError, match="torch"):
        get_backend("torch")
