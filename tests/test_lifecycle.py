"""Container lifecycle tests: churn-safety under re-registration, refcounted
GC, fsck detection/repair, near-identical re-ingest, and backward-compatible
load of PR-1-era (format v1) indexes.

These cover the ROADMAP's re-registration hazard end to end: dependants pin
the container *generation* they were ingested against, so overwriting a key
can never orphan earlier dedup records or BitX deltas.
"""

import base64
import json
import os
import struct
import zlib

import numpy as np
import pytest

from repro.core.lifecycle import ContainerLifecycle, make_vid
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st


def _write_model(path, rng, n_tensors=5, n=2048, scale=0.02, metadata=None):
    tensors = {f"model.t{i}.weight": (rng.randn(n) * scale).astype(np.float32)
               for i in range(n_tensors)}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path, metadata=metadata)
    return tensors


def _write_tensors(path, tensors, metadata=None):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    st.save_file(tensors, path, metadata=metadata)


def _write_finetune(path, base_tensors, rng, sigma=1e-3):
    ft = {k: (v + rng.randn(*v.shape).astype(np.float32) * sigma).astype(np.float32)
          for k, v in base_tensors.items()}
    _write_tensors(path, ft)
    return ft


def _read(path):
    with open(path, "rb") as f:
        return f.read()


@pytest.fixture
def churn(tmp_path):
    """Base + fine-tune ingested; returns (store, paths dict, tensors dict)."""
    rng = np.random.RandomState(0)
    base_path = str(tmp_path / "hub" / "base" / "model.safetensors")
    ft_path = str(tmp_path / "hub" / "ft" / "model.safetensors")
    base = _write_model(base_path, rng)
    ft = _write_finetune(ft_path, base, rng)
    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_file(base_path, "org/base")
    res = store.ingest_file(ft_path, "u/ft", declared_base="org/base/model.safetensors")
    assert res.n_bitx > 0  # the fine-tune really delta-compresses
    yield store, {"base": base_path, "ft": ft_path}, {"base": base, "ft": ft}
    store.close()


# ---------------------------------------------------------------------------
# The ROADMAP hazard: re-register base, old fine-tune must survive
# ---------------------------------------------------------------------------

def test_reregister_base_preserves_finetune_then_gc_reclaims(churn, tmp_path):
    """Acceptance scenario: register base → ingest fine-tune → re-register
    the base key with different weights → the fine-tune retrieves
    BIT-IDENTICAL (its BitX records resolve against the pinned old
    generation); deleting the fine-tune lets gc() reclaim the superseded
    generation, and fsck() reports zero dangling references throughout."""
    store, paths, _ = churn
    orig_ft = _read(paths["ft"])

    # v2: unrelated weights (large bit distance keeps it standalone), same
    # shapes, SAME key — the copy-on-write re-registration
    v2_path = str(tmp_path / "hub" / "v2" / "model.safetensors")
    _write_model(v2_path, np.random.RandomState(99), scale=1.0)
    store.ingest_file(v2_path, "org/base")
    assert store.file_index["org/base/model.safetensors"]["gen"] == 1
    assert store.lifecycle.exists("org/base/model.safetensors", 0)  # pinned

    # the ROADMAP hazard, closed: old fine-tune still bit-identical
    assert store.retrieve_file("u/ft", "model.safetensors") == orig_ft
    assert store.fsck(spot_check=None).ok

    # the superseded generation is referenced — gc() must NOT touch it
    assert store.gc()["collected"] == 0
    assert store.retrieve_file("u/ft", "model.safetensors") == orig_ft

    # delete the last dependant: the cascade reclaims ft@g0 AND base@g0
    assert store.delete_file("u/ft", "model.safetensors")
    swept = store.gc()
    assert swept["collected"] == 2 and swept["reclaimed_bytes"] > 0
    assert not store.lifecycle.exists("org/base/model.safetensors", 0)
    assert not os.path.exists(
        os.path.join(str(tmp_path / "store"), "containers",
                     "org/base/model.safetensors.bitx"))

    # survivor (the new generation) intact, zero dangling refs
    assert store.retrieve_file("org/base", "model.safetensors") == _read(v2_path)
    report = store.fsck(spot_check=None)
    assert report.ok and not report.dangling
    assert store.stats.reclaimed_bytes == swept["reclaimed_bytes"]
    assert store.summary()["lifecycle"]["collected"] == 2


def test_delete_gc_retrieve_survivor_bit_identity(churn, tmp_path):
    """Two fine-tunes share a base; deleting one and collecting must leave
    the other (and the base) bit-identical, and reclaim only the deleted
    container."""
    store, paths, tensors = churn
    ft2_path = str(tmp_path / "hub" / "ft2" / "model.safetensors")
    _write_finetune(ft2_path, tensors["base"], np.random.RandomState(7))
    store.ingest_file(ft2_path, "u2/ft2", declared_base="org/base/model.safetensors")

    live_before = store.lifecycle.live_bytes()
    assert store.delete_file("u2/ft2", "model.safetensors")
    swept = store.gc()
    assert swept["collected"] == 1
    assert store.lifecycle.live_bytes() == live_before - swept["reclaimed_bytes"]
    with pytest.raises(KeyError):
        store.retrieve_file("u2/ft2", "model.safetensors")
    assert store.retrieve_file("u/ft", "model.safetensors") == _read(paths["ft"])
    assert store.retrieve_file("org/base", "model.safetensors") == _read(paths["base"])
    assert store.fsck(spot_check=None).ok


def test_filededup_alias_survives_delete_of_original(churn, tmp_path):
    """A whole-file duplicate pins the generation of its target, so deleting
    the ORIGINAL key keeps the alias retrievable (and gc keeps the bytes)."""
    store, paths, _ = churn
    copy_path = str(tmp_path / "hub" / "copy" / "model.safetensors")
    os.makedirs(os.path.dirname(copy_path), exist_ok=True)
    with open(copy_path, "wb") as f:
        f.write(_read(paths["base"]))
    res = store.ingest_file(copy_path, "mirror/base")
    assert res.file_dedup_hit
    assert store.file_index["mirror/base/model.safetensors"]["ref_gen"] == 0

    assert store.delete_file("org/base", "model.safetensors")
    assert store.gc()["collected"] == 0  # alias + fine-tune still pin it
    assert store.retrieve_file("mirror/base", "model.safetensors") == _read(paths["base"])
    assert store.fsck(spot_check=None).ok
    # the file hash now resolves to the surviving alias for future dedup
    fhash = store.file_index["mirror/base/model.safetensors"]["file_hash"]
    assert store.file_hash_to_key[fhash] == "mirror/base/model.safetensors"


def test_delete_repo_drops_family_registration(churn, tmp_path):
    store, paths, _ = churn
    assert store.delete_repo("u/ft") == 1
    assert store.gc()["collected"] == 1
    assert store.delete_repo("org/base") == 1
    assert store.gc()["collected"] == 1
    assert store.lifecycle.versions == {}
    assert store.stats.n_deleted == 2
    # family/base registrations are gone: a fresh standalone ingest of the
    # same shapes must not match the deleted base
    fresh_path = str(tmp_path / "hub" / "fresh" / "model.safetensors")
    _write_model(fresh_path, np.random.RandomState(3))
    res = store.ingest_file(fresh_path, "org2/fresh")
    assert res.base_id is None and res.n_zipnn > 0
    assert store.fsck(spot_check=None).ok


# ---------------------------------------------------------------------------
# Near-identical re-ingest (same tensors, different header metadata)
# ---------------------------------------------------------------------------

def test_near_identical_reingest_writes_no_container(churn, tmp_path):
    store, paths, tensors = churn
    nd_path = str(tmp_path / "hub" / "nd" / "model.safetensors")
    _write_tensors(nd_path, tensors["base"], metadata={"note": "same tensors"})
    assert _read(nd_path) != _read(paths["base"])  # header genuinely differs

    n_versions = len(store.lifecycle.versions)
    res = store.ingest_file(nd_path, "mirror2/base")
    assert res.near_dup_hit and not res.file_dedup_hit
    assert res.n_dedup == res.n_tensors == 5
    # no new container version — only the header blob is stored
    assert len(store.lifecycle.versions) == n_versions
    assert store.file_index["mirror2/base/model.safetensors"]["kind"] == "near_dup"
    assert res.stored_bytes < 1024
    assert store.retrieve_file("mirror2/base", "model.safetensors") == _read(nd_path)
    assert store.fsck(spot_check=None).ok


def test_near_identical_reingest_same_key(churn, tmp_path):
    """Re-registering a key with identical tensors but new header metadata
    must pin the existing generation instead of writing a new container."""
    store, paths, tensors = churn
    nd_path = str(tmp_path / "hub" / "ndk" / "model.safetensors")
    _write_tensors(nd_path, tensors["base"], metadata={"rev": "2"})
    res = store.ingest_file(nd_path, "org/base")
    assert res.near_dup_hit
    rec = store.file_index["org/base/model.safetensors"]
    assert rec["kind"] == "near_dup" and rec["ref_gen"] == 0
    assert store.retrieve_file("org/base", "model.safetensors") == _read(nd_path)
    # old dependants unaffected, nothing reclaimable (near_dup anchors gen 0)
    assert store.retrieve_file("u/ft", "model.safetensors") == _read(paths["ft"])
    assert store.gc()["collected"] == 0
    assert store.fsck(spot_check=None).ok


# ---------------------------------------------------------------------------
# fsck: corruption detection, quarantine, re-pin repair
# ---------------------------------------------------------------------------

def _corrupt_payload(cpath: str) -> None:
    """Flip bytes in the middle of the frame payload (header left intact)."""
    blob = bytearray(_read(cpath))
    (hlen,) = struct.unpack("<Q", bytes(blob[8:16]))
    payload_start = 16 + hlen
    mid = payload_start + (len(blob) - payload_start) // 2
    for i in range(mid, min(mid + 8, len(blob))):
        blob[i] ^= 0xFF
    with open(cpath, "wb") as f:
        f.write(bytes(blob))


def test_fsck_corruption_roundtrip(tmp_path):
    """fsck must flag a deliberately corrupted container, and repair=True
    must quarantine it (retrieval then fails loudly instead of silently
    returning bad bytes)."""
    rng = np.random.RandomState(1)
    base_path = str(tmp_path / "hub" / "b" / "model.safetensors")
    ft_path = str(tmp_path / "hub" / "f" / "model.safetensors")
    base = _write_model(base_path, rng)
    _write_finetune(ft_path, base, rng)
    root = str(tmp_path / "store")
    with ZLLMStore(root) as s1:
        s1.ingest_file(base_path, "org/b")
        s1.ingest_file(ft_path, "u/f", declared_base="org/b/model.safetensors")
        assert s1.fsck(spot_check=None).ok
        s1.save_index()
        ft_cpath = s1.file_index["u/f/model.safetensors"]["path"]

    _corrupt_payload(ft_cpath)

    with ZLLMStore(root) as s2:
        assert s2.load_index()
        report = s2.fsck(spot_check=None)
        assert not report.ok and report.corrupt
        assert any("u/f/model.safetensors" in vid for vid, _ in report.corrupt)

        # repair: quarantine the corrupt container, keep the graph node
        report2 = s2.fsck(repair=True, spot_check=None)
        assert report2.quarantined
        assert not os.path.exists(ft_cpath)
        assert os.path.isdir(os.path.join(root, "quarantine"))
        with pytest.raises(RuntimeError, match="quarantine"):
            s2.retrieve_file("u/f", "model.safetensors")
        # the base is untouched and still clean
        assert s2.retrieve_file("org/b", "model.safetensors") == _read(base_path)
        assert s2.fsck(spot_check=None).ok  # quarantined ≠ dangling/corrupt


def test_fsck_blames_corrupt_base_not_its_dependants(tmp_path):
    """Corruption in a BASE container must quarantine only the base: the
    fine-tune's frames are healthy, so cascading quarantine would destroy
    good data (regression: decode-through-dependency used to blame the
    dependant)."""
    rng = np.random.RandomState(4)
    base_path = str(tmp_path / "hub" / "b" / "model.safetensors")
    ft_path = str(tmp_path / "hub" / "f" / "model.safetensors")
    base = _write_model(base_path, rng)
    _write_finetune(ft_path, base, rng)
    root = str(tmp_path / "store")
    with ZLLMStore(root) as s1:
        s1.ingest_file(base_path, "org/b")
        s1.ingest_file(ft_path, "u/f", declared_base="org/b/model.safetensors")
        s1.save_index()
        base_cpath = s1.file_index["org/b/model.safetensors"]["path"]

    _corrupt_payload(base_cpath)

    with ZLLMStore(root) as s2:
        assert s2.load_index()
        report = s2.fsck(repair=True, spot_check=None)
        base_vid = make_vid("org/b/model.safetensors", 0)
        ft_vid = make_vid("u/f/model.safetensors", 0)
        assert base_vid in report.quarantined
        assert ft_vid not in report.quarantined
        assert not s2.lifecycle.versions[ft_vid].quarantined
        # the fine-tune's base refs are now dangling (no surviving copy) —
        # reported, not silently dropped
        assert any(owner == ft_vid for owner, _ in report.dangling)


def test_delete_base_file_unregisters_family(tmp_path):
    """After delete_file of a base, bit-distance matching must not keep
    electing it (regression: new fine-tunes silently fell back to zipnn
    while still claiming the deleted base_id)."""
    rng = np.random.RandomState(5)
    base_path = str(tmp_path / "hub" / "b" / "model.safetensors")
    base = _write_model(base_path, rng)
    with ZLLMStore(str(tmp_path / "store")) as s:
        s.ingest_file(base_path, "org/b")
        assert s.delete_file("org/b", "model.safetensors")
        ft_path = str(tmp_path / "hub" / "f" / "model.safetensors")
        _write_finetune(ft_path, base, rng)
        res = s.ingest_file(ft_path, "u/f")
        assert res.base_id is None and res.n_zipnn > 0  # honest standalone
        assert s.retrieve_file("u/f", "model.safetensors") == _read(ft_path)
        assert s.fsck(spot_check=None).ok


def test_fsck_repair_repins_dangling_ref(tmp_path):
    """A tensor_locations entry pointing at a dead generation is dangling;
    repair must re-pin it to a surviving payload copy and restore retrieval."""
    rng = np.random.RandomState(2)
    base_path = str(tmp_path / "hub" / "b" / "model.safetensors")
    ft_path = str(tmp_path / "hub" / "f" / "model.safetensors")
    base = _write_model(base_path, rng)
    _write_finetune(ft_path, base, rng)
    root = str(tmp_path / "store")
    with ZLLMStore(root) as s1:
        s1.ingest_file(base_path, "org/b")
        s1.ingest_file(ft_path, "u/f", declared_base="org/b/model.safetensors")
        s1.save_index()

    with ZLLMStore(root) as s2:
        assert s2.load_index()
        # sabotage: point one base-tensor hash at a generation that never
        # existed (simulates a lost/partially-written index)
        thash = next(h for h, (k, g, i) in s2.tensor_locations.items()
                     if k == "org/b/model.safetensors")
        k, g, i = s2.tensor_locations[thash]
        s2.tensor_locations[thash] = (k, 999, i)

        report = s2.fsck(spot_check=0)
        assert any(thash[:12] in msg for _, msg in report.dangling)

        report2 = s2.fsck(repair=True, spot_check=0)
        assert report2.repaired and report2.ok
        assert s2.tensor_locations[thash] == (k, 0, i)
        assert s2.retrieve_file("u/f", "model.safetensors") == _read(ft_path)


# ---------------------------------------------------------------------------
# Backward-compat: loading a PR-1-era (format v1) index
# ---------------------------------------------------------------------------

def _downgrade_index_to_v1(index_path: str) -> None:
    """Rewrite a v2 index the way PR 1 wrote it: no format tag, no lifecycle
    section, 2-tuple tensor locations, no generation fields."""
    idx = json.load(open(index_path))
    assert idx["format"] == 4
    del idx["format"]
    del idx["lifecycle"]
    idx.pop("gc_cursor", None)  # v3-only key
    idx["tensor_locations"] = {h: [loc[0], loc[2]]
                               for h, loc in idx["tensor_locations"].items()}
    for rec in idx["file_index"].values():
        assert rec.get("gen", rec.get("ref_gen", 0)) == 0  # v1 had no gens
        rec.pop("gen", None)
        rec.pop("ref_gen", None)
    for k in ("live_bytes", "reclaimed_bytes", "n_deleted", "n_near_dup",
              "compaction_reclaimed_bytes", "compact_runs", "gc_max_pause_ms"):
        idx["stats"].pop(k, None)
    with open(index_path, "w") as f:
        json.dump(idx, f)


def test_load_v1_index_backward_compat(tmp_path):
    """A PR-1-era index (no generations, no lifecycle graph) must load: gen-0
    pins are synthesized, the dependency graph is rebuilt from container
    headers, and churn operations work immediately after."""
    rng = np.random.RandomState(3)
    base_path = str(tmp_path / "hub" / "b" / "model.safetensors")
    ft_path = str(tmp_path / "hub" / "f" / "model.safetensors")
    copy_path = str(tmp_path / "hub" / "c" / "model.safetensors")
    base = _write_model(base_path, rng)
    _write_finetune(ft_path, base, rng)
    os.makedirs(os.path.dirname(copy_path), exist_ok=True)
    with open(copy_path, "wb") as f:
        f.write(_read(base_path))

    root = str(tmp_path / "store")
    with ZLLMStore(root) as s1:
        s1.ingest_file(base_path, "org/b")
        s1.ingest_file(ft_path, "u/f", declared_base="org/b/model.safetensors")
        assert s1.ingest_file(copy_path, "mirror/b").file_dedup_hit
        index_path = s1.save_index()

    _downgrade_index_to_v1(index_path)

    with ZLLMStore(root) as s2:
        assert s2.load_index()
        # pins synthesized at gen 0, graph rebuilt from container headers
        assert s2.tensor_locations and all(
            len(loc) == 3 and loc[1] == 0 for loc in s2.tensor_locations.values())
        assert s2.lifecycle.exists("org/b/model.safetensors", 0)
        ft_vid = make_vid("u/f/model.safetensors", 0)
        assert make_vid("org/b/model.safetensors", 0) in s2.lifecycle.edges[ft_vid]
        assert s2.fsck(spot_check=None).ok

        # all three files retrieve bit-exactly (verify=True checks sha256)
        assert s2.retrieve_file("org/b", "model.safetensors") == _read(base_path)
        assert s2.retrieve_file("u/f", "model.safetensors") == _read(ft_path)
        assert s2.retrieve_file("mirror/b", "model.safetensors") == _read(base_path)

        # churn works on the upgraded store: re-register + delete + gc
        v2_path = str(tmp_path / "hub" / "v2" / "model.safetensors")
        _write_model(v2_path, np.random.RandomState(77), scale=1.0)
        s2.ingest_file(v2_path, "org/b")
        assert s2.retrieve_file("u/f", "model.safetensors") == _read(ft_path)
        s2.delete_file("u/f", "model.safetensors")
        s2.delete_file("mirror/b", "model.safetensors")
        assert s2.gc()["collected"] == 2  # ft@g0 + superseded base@g0
        assert s2.fsck(spot_check=None).ok
        assert s2.retrieve_file("org/b", "model.safetensors") == _read(v2_path)


def test_reregistration_releases_old_file_hash(churn, tmp_path):
    """After re-registering a key with new content, an upload identical to
    the OLD content must not dedup against the key's new generation
    (regression: the stale file_hash_to_key entry pinned wrong bytes)."""
    store, paths, _ = churn
    v2_path = str(tmp_path / "hub" / "v2" / "model.safetensors")
    _write_model(v2_path, np.random.RandomState(99), scale=1.0)
    store.ingest_file(v2_path, "org/base")  # re-register: v1 hash released

    copy_path = str(tmp_path / "hub" / "v1copy" / "model.safetensors")
    os.makedirs(os.path.dirname(copy_path), exist_ok=True)
    with open(copy_path, "wb") as f:
        f.write(_read(paths["base"]))  # byte-identical to the OLD v1 content
    res = store.ingest_file(copy_path, "mirror/v1")
    assert not res.file_dedup_hit  # stored fresh (near-dup against pinned g0 ok)
    assert store.retrieve_file("mirror/v1", "model.safetensors") == _read(paths["base"])
    assert store.fsck(spot_check=None).ok


def test_quarantine_scrubs_pool_hashes(tmp_path):
    """Ingest after a quarantine must re-store tensors whose only payload
    lived in the quarantined container — not emit dedup records retrieval
    refuses to follow (regression)."""
    rng = np.random.RandomState(6)
    a_path = str(tmp_path / "hub" / "a" / "model.safetensors")
    a = _write_model(a_path, rng)
    root = str(tmp_path / "store")
    with ZLLMStore(root) as s1:
        s1.ingest_file(a_path, "org/a")
        s1.save_index()
        cpath = s1.file_index["org/a/model.safetensors"]["path"]
    _corrupt_payload(cpath)

    with ZLLMStore(root) as s2:
        assert s2.load_index()
        assert s2.fsck(repair=True, spot_check=None).quarantined
        # new file shares a's tensors (plus one extra): must NOT dedup
        # against the quarantined payload
        b = dict(a)
        b["model.extra.weight"] = (np.arange(64) / 64).astype(np.float32)
        b_path = str(tmp_path / "hub" / "b" / "model.safetensors")
        _write_tensors(b_path, b)
        res = s2.ingest_file(b_path, "org/b")
        assert res.n_dedup == 0  # everything re-stored fresh
        assert s2.retrieve_file("org/b", "model.safetensors") == _read(b_path)
        assert s2.fsck(spot_check=None).ok


def test_single_fsck_pass_reports_dependants_of_quarantined_target(tmp_path):
    """fsck quarantines a corrupt target in pass 1 and judges its dependants
    against that state in pass 2 — ONE invocation reports the dangling refs
    (regression: a dependant sorted before its target was reported clean)."""
    rng = np.random.RandomState(8)
    # key "org/z" sorts AFTER dependant "org/a": the old single walk checked
    # a's refs before z was quarantined
    z_path = str(tmp_path / "hub" / "z" / "model.safetensors")
    z = _write_model(z_path, rng)
    a = dict(z)
    a["model.extra.weight"] = (np.arange(64) / 64).astype(np.float32)
    a_path = str(tmp_path / "hub" / "a" / "model.safetensors")
    _write_tensors(a_path, a)
    root = str(tmp_path / "store")
    with ZLLMStore(root) as s1:
        s1.ingest_file(z_path, "org/z")
        res = s1.ingest_file(a_path, "org/a")
        assert res.n_dedup == 5  # a's container dedup-references z's payload
        s1.save_index()
        z_cpath = s1.file_index["org/z/model.safetensors"]["path"]
    _corrupt_payload(z_cpath)

    with ZLLMStore(root) as s2:
        assert s2.load_index()
        report = s2.fsck(repair=True, spot_check=None)
        assert make_vid("org/z/model.safetensors", 0) in report.quarantined
        a_vid = make_vid("org/a/model.safetensors", 0)
        # the dependant's now-dangling refs surface in the SAME pass
        assert any(owner == a_vid for owner, _ in report.dangling)


def test_gc_keeps_dependencies_of_quarantined_versions(tmp_path):
    """A quarantined dependant is a GC root: its BitX base must survive
    gc() so a later restore/repair still resolves (regression)."""
    rng = np.random.RandomState(9)
    base_path = str(tmp_path / "hub" / "b" / "model.safetensors")
    ft_path = str(tmp_path / "hub" / "f" / "model.safetensors")
    base = _write_model(base_path, rng)
    _write_finetune(ft_path, base, rng)
    root = str(tmp_path / "store")
    with ZLLMStore(root) as s1:
        s1.ingest_file(base_path, "org/b")
        s1.ingest_file(ft_path, "u/f", declared_base="org/b/model.safetensors")
        s1.save_index()
        ft_cpath = s1.file_index["u/f/model.safetensors"]["path"]
    _corrupt_payload(ft_cpath)

    with ZLLMStore(root) as s2:
        assert s2.load_index()
        s2.fsck(repair=True, spot_check=None)  # quarantines the fine-tune
        # delete BOTH index entries: only the quarantine pins anything now
        s2.delete_file("u/f", "model.safetensors")
        s2.delete_file("org/b", "model.safetensors")
        s2.gc()
        # the quarantined fine-tune AND its base survive the sweep
        assert s2.lifecycle.get("u/f/model.safetensors", 0).quarantined
        assert s2.lifecycle.exists("org/b/model.safetensors", 0)


def test_lifecycle_graph_json_roundtrip():
    lc = ContainerLifecycle()
    lc.register_version("a/m.safetensors", 0, "/tmp/a.bitx", 100)
    lc.register_version("a/m.safetensors", 1, "/tmp/a@g1.bitx", 120)
    lc.register_version("b/m.safetensors", 0, "/tmp/b.bitx", 90)
    lc.add_edge(make_vid("b/m.safetensors", 0), make_vid("a/m.safetensors", 0))
    back = ContainerLifecycle.from_json(lc.to_json())
    assert back.versions.keys() == lc.versions.keys()
    assert back.max_gen == lc.max_gen == {"a/m.safetensors": 1, "b/m.safetensors": 0}
    assert back.edges == lc.edges
    # collect with only b anchored: a@g0 survives via the edge, a@g1 goes
    reclaimed = back.collect({make_vid("b/m.safetensors", 0)})
    assert [v.vid for v in reclaimed] == [make_vid("a/m.safetensors", 1)]
    assert back.next_generation("a/m.safetensors") == 2  # gens never reused


# ---------------------------------------------------------------------------
# Satellites: fsck orphan scan, deterministic mmap lifecycle (fd leak)
# ---------------------------------------------------------------------------

def test_fsck_orphan_scan_flags_and_repairs_crash_debris(churn, tmp_path):
    """Containers on disk referenced by no index entry (an interrupted
    ingest's debris) are flagged by fsck and deleted under repair=True;
    legitimate containers and the quarantine/ dir are never touched."""
    store, paths, _ = churn
    croot = os.path.join(store.root, "containers")
    debris = [os.path.join(croot, "org", "crashed@g3.bitx"),
              os.path.join(croot, "stray.bitx")]
    for p in debris:
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(b"BITX0001" + b"\x00" * 64)  # plausible junk
    # a non-container file in the tree is ignored entirely
    with open(os.path.join(croot, "notes.txt"), "w") as f:
        f.write("not a container")

    report = store.fsck(repair=False, spot_check=None)
    assert sorted(report.orphans) == sorted(os.path.abspath(p) for p in debris)
    assert report.ok  # orphans are debris, not corruption of live state
    assert all(os.path.exists(p) for p in debris)  # repair=False only flags

    report = store.fsck(repair=True, spot_check=None)
    assert len(report.orphans) == 2 and len(report.repaired) >= 2
    assert not any(os.path.exists(p) for p in debris)
    after = store.fsck(repair=False, spot_check=None)
    assert after.ok and not after.orphans
    # live data untouched by the orphan sweep
    assert store.retrieve_file("u/ft", "model.safetensors") == _read(paths["ft"])


def test_fsck_recognizes_half_written_compact_temp_as_debris(churn, tmp_path):
    """Satellite fix: a ``.bitx.part`` temp file (container write killed
    between temp write and atomic rename — e.g. a crashed compact()) is
    crash debris, not corruption: flagged as an orphan, deleted under
    repair=True, and — unlike real containers — deletable even when the
    version graph is empty (a temp path can never be referenced)."""
    store, paths, _ = churn
    croot = os.path.join(store.root, "containers")
    part = os.path.join(croot, ".compact", "pool@g1.bitx.part")
    os.makedirs(os.path.dirname(part), exist_ok=True)
    with open(part, "wb") as f:
        f.write(b"BITX0001" + b"\x00" * 40)  # truncated half-write

    report = store.fsck(repair=False, spot_check=None)
    assert os.path.abspath(part) in report.orphans
    assert report.ok and not report.corrupt  # debris, not corruption
    assert os.path.exists(part)              # repair=False only flags

    report = store.fsck(repair=True, spot_check=None)
    assert not os.path.exists(part)
    assert any(p == os.path.abspath(part) for p, _ in report.repaired)
    # live data untouched
    assert store.retrieve_file("u/ft", "model.safetensors") == _read(paths["ft"])

    # graph-empty safety: a fresh store (index NOT loaded) still deletes
    # temp debris while refusing to touch real containers
    fresh = ZLLMStore(store.root)
    with open(part, "wb") as f:
        f.write(b"junk")
    rep = fresh.fsck(repair=True, spot_check=0)
    assert not os.path.exists(part), "temp debris must be deletable always"
    assert any("refused" in msg for _, msg in rep.dangling)  # real containers kept
    fresh.close()


def _open_fds():
    return len(os.listdir("/proc/self/fd"))


def test_reader_fds_stable_under_gc_retrieve_churn(tmp_path):
    """Regression: LRU-evicted and gc-evicted BitXReaders must close their
    mmaps deterministically. A tiny reader cache churned over many
    containers across repeated gc+retrieve rounds must not grow the
    process's open-fd count."""
    if not os.path.isdir("/proc/self/fd"):
        pytest.skip("needs /proc (Linux)")
    n_repos = 6
    paths = {}
    for i in range(n_repos):
        p = str(tmp_path / "hub" / f"org{i}" / "m" / "model.safetensors")
        _write_model(p, np.random.RandomState(200 + i), scale=1.0)
        paths[f"org{i}/m"] = p
    store = ZLLMStore(str(tmp_path / "store"), reader_cache_size=2, workers=0)
    for rid, p in paths.items():
        store.ingest_file(p, rid)

    for rid in paths:  # warm every reader once (cache size 2 => churn)
        store.retrieve_file(rid, "model.safetensors", verify=False)
    baseline = _open_fds()
    victims = ["org4/m", "org5/m"]
    for round_ in range(3):
        for rid, p in paths.items():
            if rid in victims:
                continue
            assert store.retrieve_file(rid, "model.safetensors") == _read(p)
        if round_ == 1:
            for rid in victims:
                store.delete_repo(rid.split("/")[0])
            swept = store.gc()
            assert swept["collected"] >= 2  # gc evicts + closes their readers
    assert _open_fds() <= baseline, "reader fds leaked across gc+retrieve churn"
    store.close()
    assert _open_fds() < baseline  # close() drops every cached map


def test_retired_reader_closes_at_last_release_not_mid_decode(tmp_path):
    """An evicted handle pinned by an in-flight decode must stay usable and
    close exactly when the pin count hits zero."""
    p = str(tmp_path / "hub" / "org" / "m" / "model.safetensors")
    _write_model(p, np.random.RandomState(77))
    store = ZLLMStore(str(tmp_path / "store"), reader_cache_size=1)
    store.ingest_file(p, "org/m")
    cpath = store.file_index["org/m/model.safetensors"]["path"]

    handle = store._acquire_reader(cpath)
    assert handle.pins == 1 and not handle.retired
    with store._cache_lock:
        store._reader_cache.pop(cpath)      # evict while pinned
    assert handle.retired
    assert handle.reader.records            # still usable: mmap not closed
    assert handle.reader.payload_size > 0
    store._release_reader(handle)           # last release closes the map
    assert handle.reader._mmap is None
    store.close()


def test_retrieve_tensor_resolves_names_via_near_dup_own_header(tmp_path):
    """Regression (found in review): a near-dup whose header RENAMES the
    tensors (record bytes identical, names permuted) must serve
    retrieve_tensor by ITS names — never silently return the target's
    same-named record."""
    rng = np.random.RandomState(88)
    x = (rng.randn(2048) * 0.02).astype(np.float32)
    y = (rng.randn(2048) * 0.02).astype(np.float32)
    a_path = str(tmp_path / "hub" / "a" / "model.safetensors")
    b_path = str(tmp_path / "hub" / "b" / "model.safetensors")
    # A: record 0 = alpha(x), record 1 = beta(y). B: identical bytes per
    # record, but record 0 is NAMED beta and record 1 alpha.
    _write_tensors(a_path, {"alpha": x, "beta": y})
    _write_tensors(b_path, {"beta": x, "alpha": y})

    store = ZLLMStore(str(tmp_path / "store"))
    store.ingest_file(a_path, "org/a")
    res = store.ingest_file(b_path, "org/b")
    assert res.near_dup_hit, "setup: B must take the near-dup path"

    data, meta = store.retrieve_tensor("org/b", "model.safetensors", "alpha")
    assert data == y.tobytes() and meta["dtype"] == "F32"
    data, _ = store.retrieve_tensor("org/b", "model.safetensors", "beta")
    assert data == x.tobytes()
    # A itself is untouched by B's renaming
    data, _ = store.retrieve_tensor("org/a", "model.safetensors", "alpha")
    assert data == x.tobytes()
    with pytest.raises(KeyError):
        store.retrieve_tensor("org/b", "model.safetensors", "gamma")
    # file-level retrieval of B stays bit-exact too
    assert store.retrieve_file("org/b", "model.safetensors") == _read(b_path)
    store.close()


def test_fsck_repair_refuses_orphan_wipe_when_index_not_loaded(tmp_path):
    """Safety regression (found in review): fsck(repair=True) on a store
    whose index was never loaded must NOT treat every container on disk as
    an orphan and wipe the store."""
    rng = np.random.RandomState(121)
    p = str(tmp_path / "hub" / "org" / "m" / "model.safetensors")
    _write_model(p, rng)
    with ZLLMStore(str(tmp_path / "store")) as s1:
        s1.ingest_file(p, "org/m")
        s1.save_index()
        cpath = s1.file_index["org/m/model.safetensors"]["path"]

    fresh = ZLLMStore(str(tmp_path / "store"))   # index NOT loaded
    report = fresh.fsck(repair=True, spot_check=0)
    assert os.path.exists(cpath), "repair wiped a store with an unloaded index"
    assert len(report.orphans) == 1
    assert any("refused" in msg for _, msg in report.dangling)
    fresh.close()

    loaded = ZLLMStore(str(tmp_path / "store"))
    assert loaded.load_index()
    report = loaded.fsck(repair=True, spot_check=None)
    assert report.ok and not report.orphans
    assert loaded.retrieve_file("org/m", "model.safetensors") == _read(p)
    loaded.close()
