"""Documentation anti-rot gates.

1. The route table in ``docs/HTTP_API.md`` must list EXACTLY the routes
   in ``repro.serve.store_server.ROUTES`` (the canonical registry the
   dispatcher is written against) — no undocumented endpoints, no phantom
   ones.
2. Every documented route, exercised with well-formed parameters against
   a live server, must answer something other than 404/405 — a row that
   the dispatcher does not actually serve fails here even if the table
   matches the registry.
3. Every fixed path in the registry appears in the dispatcher source.
4. The gated-metric table in ``docs/BENCHMARKS.md`` must list EXACTLY
   the suffixes in ``benchmarks.check_regression``'s ``GATED_SUFFIXES``
   / ``GATED_INVERSE_SUFFIXES`` with the right direction — same
   live-gating pattern, different registry.
5. ``tools/check_docs.py`` finds no dangling links/anchors in
   ``docs/*.md`` or the repo's READMEs.
"""

import http.client
import inspect
import json
import os
import re
import subprocess
import sys

import numpy as np
import pytest

import repro.serve.store_server as store_server_mod
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st
from repro.serve.store_server import ROUTES, ServerThread

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HTTP_API_MD = os.path.join(REPO_ROOT, "docs", "HTTP_API.md")
BENCHMARKS_MD = os.path.join(REPO_ROOT, "docs", "BENCHMARKS.md")

# `| `METHOD /path` | summary |` rows of the Routes table; the in-code-span
# pipe of GET|POST is escaped as \| per GFM table rules
DOC_ROW_RE = re.compile(r"^\|\s*`([A-Z\\|]+)\s+(/[^`]*)`\s*\|")

# `| `suffix` | higher/lower | ...` rows of the gated-key catalog
METRIC_ROW_RE = re.compile(r"^\|\s*`([\w.]+)`\s*\|\s*(higher|lower)\s*\|")


def documented_routes():
    rows = []
    for line in open(HTTP_API_MD, encoding="utf-8"):
        m = DOC_ROW_RE.match(line)
        if m:
            rows.append((m.group(1).replace("\\|", "|"), m.group(2)))
    return rows


def test_route_table_matches_server_registry():
    doc = documented_routes()
    assert doc, "docs/HTTP_API.md has no parsable route table"
    registry = [(methods, path) for methods, path, _ in ROUTES]
    assert sorted(doc) == sorted(registry), (
        "docs/HTTP_API.md route table diverged from store_server.ROUTES:\n"
        f"  documented only: {sorted(set(doc) - set(registry))}\n"
        f"  registered only: {sorted(set(registry) - set(doc))}")
    # and no duplicate rows on either side
    assert len(doc) == len(set(doc))
    assert len(registry) == len(set(registry))


def test_fixed_route_paths_appear_in_dispatcher():
    """The registry itself must not rot against the hand-written dispatch:
    every fixed (parameter-free) path literal occurs in the server
    source, and the parametrized ones have their marker segments."""
    src = inspect.getsource(store_server_mod)
    for methods, path, _ in ROUTES:
        if "{" not in path:
            assert f'"{path}"' in src, f"route {path} not found in dispatcher"
    assert 'segs[-2] == "file"' in src          # file route marker
    assert '"tensor" in segs[2:-1]' in src      # tensor route marker


@pytest.fixture(scope="module")
def live_server(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("docs-live")
    rng = np.random.RandomState(0)
    model = str(tmp / "hub" / "model.safetensors")
    os.makedirs(os.path.dirname(model))
    st.save_file({"t.weight": (rng.randn(512) * 0.02).astype(np.float32)},
                 model)
    repo_dir = str(tmp / "hub2")
    os.makedirs(repo_dir)
    st.save_file({"t.weight": (rng.randn(512) * 0.02).astype(np.float32)},
                 os.path.join(repo_dir, "model.safetensors"))
    store = ZLLMStore(str(tmp / "store"), workers=0)
    store.ingest_file(model, "org/doc")
    with ServerThread(store, max_concurrency=2) as srv:
        yield srv, model, repo_dir
    store.close()


def test_every_documented_route_is_served(live_server):
    """Probe each documented (method, path) with well-formed parameters:
    none may come back 404/405 — that would be a phantom row."""
    srv, model, repo_dir = live_server
    body_for = {
        ("PUT", "/repo/{repo_id}/file/{filename}"):
            open(model, "rb").read(),
        ("POST", "/ingest_repo"):
            json.dumps({"dir": repo_dir, "repo_id": "org/doc2",
                        "sync": True}).encode(),
        ("POST", "/peer/tombstones"):
            json.dumps({"tombstones":
                        [["org/gone/model.safetensors", 0, 1.0]]}).encode(),
    }
    # routes whose well-formed probe needs query parameters: the adopt
    # route is polled with a ?stat=1 offset probe (mutates nothing but
    # exercises the real parameter validation + spool stat path)
    query_for = {
        ("POST", "/peer/adopt"):
            "?stat=1&key=org/doc/model.safetensors&gen=0&total=1&sha256="
            + "0" * 64,
    }
    fill = {"{repo_id}": "org/doc", "{filename}": "model.safetensors",
            "{tensor_name}": "t.weight",
            "{key@gN}": "org/doc/model.safetensors@g0"}
    conn = http.client.HTTPConnection(srv.host, srv.port, timeout=60)
    try:
        for methods, path, _ in ROUTES:
            concrete = path
            for k, v in fill.items():
                concrete = concrete.replace(k, v)
            for method in methods.split("|"):
                query = query_for.get((method, path), "")
                if method == "PUT":
                    query = "?sync=1"
                conn.request(method, concrete + query,
                             body=body_for.get((method, path)))
                r = conn.getresponse()
                payload = r.read()
                assert r.status not in (404, 405), (
                    f"documented route {method} {path} answered "
                    f"{r.status}: {payload[:200]!r}")
    finally:
        conn.close()


def test_gated_metric_table_matches_regression_registries():
    """docs/BENCHMARKS.md's catalog must mirror check_regression's gate
    registries exactly — suffix AND direction. A suffix gated in code but
    undocumented (or documented but ungated, or flipped direction) fails."""
    from benchmarks.check_regression import (GATED_INVERSE_SUFFIXES,
                                             GATED_SUFFIXES)
    doc = []
    for line in open(BENCHMARKS_MD, encoding="utf-8"):
        m = METRIC_ROW_RE.match(line)
        if m:
            doc.append((m.group(1), m.group(2)))
    assert doc, "docs/BENCHMARKS.md has no parsable gated-key table"
    registry = ([(s, "higher") for s in GATED_SUFFIXES]
                + [(s, "lower") for s in GATED_INVERSE_SUFFIXES])
    assert sorted(doc) == sorted(registry), (
        "docs/BENCHMARKS.md gated-key table diverged from "
        "check_regression registries:\n"
        f"  documented only: {sorted(set(doc) - set(registry))}\n"
        f"  gated only:      {sorted(set(registry) - set(doc))}")
    assert len(doc) == len(set(doc))


def test_gated_metrics_emitted_by_tiny_baseline():
    """Every higher-is-better gated suffix must match at least one numeric
    key in the COMMITTED tiny baseline — a gate whose metric no bench
    emits would silently never be enforced (warn-on-missing semantics)."""
    from benchmarks.check_regression import GATED_SUFFIXES, _flatten
    baseline_path = os.path.join(REPO_ROOT, "experiments", "bench",
                                 "throughput.json")
    flat = _flatten(json.load(open(baseline_path)))
    for suffix in GATED_SUFFIXES:
        hits = [k for k, v in flat.items()
                if k.endswith(suffix) and isinstance(v, (int, float))]
        assert hits, (f"gated suffix {suffix!r} matches no numeric key in "
                      f"the committed baseline {baseline_path}")


def test_docs_links_and_anchors_resolve():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "tools", "check_docs.py"),
         REPO_ROOT],
        capture_output=True, text=True)
    assert proc.returncode == 0, (
        f"dangling documentation references:\n{proc.stderr}")
