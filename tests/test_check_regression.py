"""CI bench regression gate semantics (benchmarks.check_regression):
shared gated keys fail on a real drop, keys present in only one file warn
instead of failing (new metrics must not hard-fail CI until the baseline is
regenerated), and the serving concurrent-retrieval metric is gated."""

from benchmarks.check_regression import (GATED_INVERSE_SUFFIXES,
                                         GATED_SUFFIXES,
                                         INVERSE_FAIL_FLOOR,
                                         INVERSE_FAIL_FLOORS, compare)


def test_shared_key_regression_fails():
    base = {"zllm": {"workers_1": {"ingest_MBps": 100.0, "retrieve_MBps": 200.0}}}
    fresh = {"zllm": {"workers_1": {"ingest_MBps": 60.0, "retrieve_MBps": 190.0}}}
    rows, failures, warnings = compare(base, fresh, max_drop=0.25)
    assert failures == ["zllm.workers_1.ingest_MBps"]
    assert not warnings and len(rows) == 2


def test_concurrent_retrieval_metric_is_gated():
    assert any("concurrent_retrieve_MBps".endswith(s) for s in GATED_SUFFIXES)
    base = {"serving": {"concurrent_retrieve_MBps": 100.0}}
    fresh = {"serving": {"concurrent_retrieve_MBps": 50.0}}
    _, failures, _ = compare(base, fresh, max_drop=0.25)
    assert failures == ["serving.concurrent_retrieve_MBps"]
    _, failures, _ = compare(base, {"serving": {"concurrent_retrieve_MBps": 90.0}},
                             max_drop=0.25)
    assert not failures


def test_missing_keys_warn_but_tolerated():
    base = {"zllm": {"ingest_MBps": 100.0, "old_retrieve_MBps": 50.0},
            "hf_fastcdc": {"retrieve_MBps": "line-rate"}}
    fresh = {"zllm": {"ingest_MBps": 99.0},
             "serving": {"concurrent_retrieve_MBps": 120.0},
             "hf_fastcdc": {"retrieve_MBps": "line-rate"}}
    rows, failures, warnings = compare(base, fresh, max_drop=0.25)
    assert not failures and len(rows) == 1
    assert len(warnings) == 2  # baseline-only AND fresh-only gated keys
    assert any("old_retrieve_MBps" in w and "missing from fresh" in w
               for w in warnings)
    assert any("concurrent_retrieve_MBps" in w and "no baseline" in w
               for w in warnings)
    # non-numeric-on-BOTH-sides ("line-rate") stays silently skipped
    assert not any("hf_fastcdc" in w for w in warnings)


def test_compaction_reclaimed_bytes_is_drop_gated():
    """The PR-4 lifecycle metric: a collapse in reclaimed bytes (compact()
    silently stopped retiring superseded generations) must fail CI."""
    assert any("compaction_reclaimed_bytes".endswith(s) for s in GATED_SUFFIXES)
    base = {"lifecycle_compaction": {"compaction_reclaimed_bytes": 400000}}
    _, failures, _ = compare(
        base, {"lifecycle_compaction": {"compaction_reclaimed_bytes": 100000}},
        max_drop=0.25)
    assert failures == ["lifecycle_compaction.compaction_reclaimed_bytes"]
    _, failures, _ = compare(
        base, {"lifecycle_compaction": {"compaction_reclaimed_bytes": 390000}},
        max_drop=0.25)
    assert not failures


def test_incremental_gc_pause_is_rise_gated():
    """Lower-is-better key: the gc pause fails only when it RISES past the
    loose multiplier (a pause collapse is an improvement, never a failure),
    and missing-on-either-side still only warns."""
    assert "incremental_gc_max_pause_ms" in GATED_INVERSE_SUFFIXES
    base = {"lifecycle_compaction": {"incremental_gc_max_pause_ms": 50.0}}
    rows, failures, _ = compare(
        base, {"lifecycle_compaction": {"incremental_gc_max_pause_ms": 500.0}},
        max_drop=0.25, max_rise=3.0)
    assert failures == ["lifecycle_compaction.incremental_gc_max_pause_ms"]
    _, failures, _ = compare(
        base, {"lifecycle_compaction": {"incremental_gc_max_pause_ms": 175.0}},
        max_drop=0.25, max_rise=3.0)
    assert not failures  # 3.5x baseline is within the 4x budget
    _, failures, _ = compare(
        base, {"lifecycle_compaction": {"incremental_gc_max_pause_ms": 0.5}},
        max_drop=0.25, max_rise=3.0)
    assert not failures  # faster is never a regression
    _, failures, _ = compare(
        {"lifecycle_compaction": {"incremental_gc_max_pause_ms": 0.3}},
        {"lifecycle_compaction": {"incremental_gc_max_pause_ms": 60.0}},
        max_drop=0.25, max_rise=3.0)
    assert not failures  # sub-floor: a full in-budget step (or scheduler
    # noise on a sub-ms baseline) never fails — only stop-the-world-scale
    # pauses past INVERSE_FAIL_FLOOR can
    _, failures, warnings = compare({}, base, max_drop=0.25)
    assert not failures
    assert any("incremental_gc_max_pause_ms" in w and "no baseline" in w
               for w in warnings)


def test_failover_read_throughput_is_drop_gated():
    """PR-6 replicated-read metric: a collapse in read throughput with one
    root down (failover fell off the skip-dead-roots fast path) must fail."""
    assert any("failover_read_MBps".endswith(s) for s in GATED_SUFFIXES)
    base = {"replication": {"failover_read_MBps": 80.0}}
    _, failures, _ = compare(
        base, {"replication": {"failover_read_MBps": 40.0}}, max_drop=0.25)
    assert failures == ["replication.failover_read_MBps"]
    _, failures, _ = compare(
        base, {"replication": {"failover_read_MBps": 75.0}}, max_drop=0.25)
    assert not failures


def test_quorum_put_p99_is_rise_gated_with_default_floor():
    """Lower-is-better quorum-write latency: fails only on a rise past the
    multiplier AND past the default ms floor (scheduler noise on a fast
    baseline never fails)."""
    assert "quorum_put_p99_ms" in GATED_INVERSE_SUFFIXES
    assert "quorum_put_p99_ms" not in INVERSE_FAIL_FLOORS  # default floor
    base = {"replication": {"quorum_put_p99_ms": 120.0}}
    _, failures, _ = compare(
        base, {"replication": {"quorum_put_p99_ms": 900.0}},
        max_drop=0.25, max_rise=3.0)
    assert failures == ["replication.quorum_put_p99_ms"]
    _, failures, _ = compare(
        {"replication": {"quorum_put_p99_ms": 10.0}},
        {"replication": {"quorum_put_p99_ms": 200.0}},  # 20x but sub-floor
        max_drop=0.25, max_rise=3.0)
    assert not failures
    _, failures, _ = compare(
        base, {"replication": {"quorum_put_p99_ms": 60.0}},
        max_drop=0.25, max_rise=3.0)
    assert not failures  # faster is never a regression


def test_anti_entropy_repair_uses_per_suffix_floor():
    """The repair sweep reports SECONDS, so the 250 default (meant for ms
    keys) would let a 4-minute repair pass on a 60 s baseline — it carries
    its own absolute floor instead."""
    assert "anti_entropy_repair_s" in GATED_INVERSE_SUFFIXES
    assert INVERSE_FAIL_FLOORS["anti_entropy_repair_s"] < INVERSE_FAIL_FLOOR
    base = {"replication": {"anti_entropy_repair_s": 2.0}}
    _, failures, _ = compare(
        base, {"replication": {"anti_entropy_repair_s": 30.0}},
        max_drop=0.25, max_rise=3.0)
    assert failures == ["replication.anti_entropy_repair_s"]
    _, failures, _ = compare(
        {"replication": {"anti_entropy_repair_s": 0.5}},
        {"replication": {"anti_entropy_repair_s": 4.0}},  # 8x but under 5 s
        max_drop=0.25, max_rise=3.0)
    assert not failures
    _, failures, warnings = compare({}, base, max_drop=0.25)
    assert not failures  # new metric warns until the baseline is regenerated
    assert any("anti_entropy_repair_s" in w and "no baseline" in w
               for w in warnings)


def test_numeric_gate_turning_string_warns():
    """A gated key flipping from numeric to string must warn — otherwise a
    throughput gate can vanish from CI with zero output."""
    base = {"zllm": {"retrieve_MBps": 28.5}}
    fresh = {"zllm": {"retrieve_MBps": "line-rate"}}
    rows, failures, warnings = compare(base, fresh, max_drop=0.25)
    assert not rows and not failures
    assert len(warnings) == 1 and "no longer numeric" in warnings[0]
    # and the reverse direction (string baseline, numeric fresh) warns too
    _, _, warnings = compare(fresh, base, max_drop=0.25)
    assert len(warnings) == 1 and "became numeric" in warnings[0]
