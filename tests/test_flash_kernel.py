"""Flash-attention Pallas kernel vs dense oracle: shape/dtype/block sweeps in
interpret mode (CPU), including causal, bidirectional and sliding-window."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import mha_reference

CASES = [
    # (B, Sq, H, D, causal, window, block_q, block_kv)
    (2, 256, 4, 128, True, 0, 128, 128),
    (1, 512, 2, 128, False, 0, 128, 256),
    (2, 256, 4, 128, True, 64, 128, 128),
    (1, 1024, 1, 128, True, 0, 256, 512),
    (1, 256, 2, 256, True, 0, 128, 128),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_reference(case, dtype):
    B, S, H, D, causal, window, bq, bkv = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(k1, (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, S, H, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, S, H, D), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_kv=bkv, interpret=True)
    want = mha_reference(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_matches_layers_attention():
    """The kernel and the XLA chunked path implement the same math."""
    from repro.models import layers as L
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (1, 4096, 2, 128), jnp.float32)
    k = jax.random.normal(k2, (1, 4096, 2, 128), jnp.float32)
    v = jax.random.normal(k3, (1, 4096, 2, 128), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=1024, block_kv=1024,
                          interpret=True)
    want = L.attention(q, k, v, causal=True)   # chunked XLA path at S=4096
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
