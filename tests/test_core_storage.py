"""Storage-core tests: safetensors format, BitX containers, dedup engines,
FastCDC, bit distance, clustering, and the full zLLM pipeline."""

import json
import os

import ml_dtypes
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as stt

from repro.core.bitdistance import (bit_distance_arrays, expected_bit_distance_mc,
                                    shape_signature)
from repro.core.bitx import BitXCodec, BitXReader, BitXWriter
from repro.core.chunkdedup import ChunkDedup, FastCDC
from repro.core.dedup import FileDedup, LayerDedup, TensorDedup, layer_key
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st

BF16 = ml_dtypes.bfloat16


# ---------------------------------------------------------------------------
# safetensors
# ---------------------------------------------------------------------------

def test_safetensors_roundtrip(tmp_path):
    rng = np.random.RandomState(0)
    tensors = {
        "a.weight": rng.randn(4, 8).astype(np.float32),
        "b.weight": rng.randn(16).astype(BF16),
        "c.ids": rng.randint(0, 100, (3, 3)).astype(np.int64),
        "d.flag": np.array([True, False]),
    }
    p = tmp_path / "m.safetensors"
    st.save_file(tensors, p, metadata={"k": "v"})
    back = st.load_file(p)
    assert set(back) == set(tensors)
    np.testing.assert_array_equal(back["a.weight"], tensors["a.weight"])
    np.testing.assert_array_equal(back["b.weight"], tensors["b.weight"].view(np.uint16))
    infos, meta, _ = st.read_header(p)
    assert meta["k"] == "v"
    assert [ti.name for ti in infos] == list(tensors)  # insertion order preserved
    assert json.loads(meta["tensor_order"]) == list(tensors)


@settings(max_examples=20, deadline=None)
@given(stt.integers(1, 64), stt.integers(0, 2**31 - 1))
def test_safetensors_property_bitexact(n, seed):
    import tempfile
    rng = np.random.RandomState(seed)
    t = {"x": rng.randn(n).astype(np.float32),
         "y": (rng.randn(n) * 100).astype(BF16)}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.safetensors")
        st.save_file(t, p)
        back = st.load_file(p)
        np.testing.assert_array_equal(back["x"], t["x"])
        np.testing.assert_array_equal(back["y"], t["y"].view(np.uint16))


# ---------------------------------------------------------------------------
# BitX codec + container
# ---------------------------------------------------------------------------

def test_bitx_codec_roundtrip_bf16():
    rng = np.random.RandomState(1)
    base = (rng.randn(4096) * 0.02).astype(BF16).view(np.uint16)
    ft = ((base.view(BF16).astype(np.float32)
           + rng.randn(4096).astype(np.float32) * 0.001).astype(BF16)).view(np.uint16)
    codec = BitXCodec()
    frames, raw = codec.encode_delta(base, ft)
    assert raw == ft.nbytes
    out = codec.decode_delta(frames, base)
    np.testing.assert_array_equal(out, ft)
    # same-family deltas: the MSB plane must compress far better than raw
    assert len(frames[0]) < 0.35 * len(base)


def test_bitx_container_roundtrip(tmp_path):
    rng = np.random.RandomState(2)
    base = rng.randn(100).astype(np.float32)
    ft = base + rng.randn(100).astype(np.float32) * 1e-4
    w = BitXWriter(file_metadata={"hello": "world"})
    w.add_bitx("t0", "F32", (100,), base, ft, "bh", "sh")
    w.add_zipnn("t1", "F32", (10, 10), rng.randn(10, 10).astype(np.float32), "sh2")
    w.add_raw("t2", "I32", (5,), np.arange(5, dtype=np.int32).tobytes(), "sh3")
    w.add_dedup("t3", "F32", (100,), "sh", 400)
    path = str(tmp_path / "c.bitx")
    w.write(path)
    r = BitXReader.open(path)
    assert r.file_metadata["hello"] == "world"
    assert [rec.codec for rec in r.records] == ["bitx", "zipnn", "raw", "dedup"]
    out = r.decode_tensor(0, lambda h: base, None)
    np.testing.assert_array_equal(out, ft.view(np.uint32).reshape(100))


# ---------------------------------------------------------------------------
# Dedup engines
# ---------------------------------------------------------------------------

def test_layer_key_grouping():
    assert layer_key("model.layers.7.mlp.w") == "layer.7"
    assert layer_key("transformer.h.12.attn.q") == "layer.12"
    assert layer_key("lm_head.weight").startswith("top.")


def test_dedup_hierarchy_on_corpus(corpus_dir):
    """TensorDedup must land between FileDedup and (Layer <= Tensor)."""
    root, manifest = corpus_dir
    fd, td, ld = FileDedup(), TensorDedup(), LayerDedup()
    for rid, kind in manifest:
        p = os.path.join(root, rid, "model.safetensors")
        fd.scan_file(p, rid)
        td.scan_file(p, rid)
        ld.scan_file(p, rid)
    assert fd.stats.reduction_ratio < td.stats.reduction_ratio
    assert ld.stats.reduction_ratio <= td.stats.reduction_ratio + 1e-9
    assert td.stats.n_unique < td.stats.n_units
    # metadata ordering: file < layer < tensor entries
    assert fd.stats.n_unique <= ld.stats.n_unique <= td.stats.n_unique


def test_fastcdc_boundaries():
    cdc = FastCDC(min_size=64, avg_size=256, max_size=1024)
    rng = np.random.RandomState(3)
    data = rng.bytes(64 * 1024)
    chunks = list(cdc.chunks(data))
    assert chunks[0][0] == 0 and chunks[-1][1] == len(data)
    for (b, e), (b2, e2) in zip(chunks, chunks[1:]):
        assert e == b2
    sizes = [e - b for b, e in chunks[:-1]]
    assert all(64 <= s <= 1024 for s in sizes)
    # determinism
    assert list(cdc.chunks(data)) == chunks


def test_fastcdc_finds_shared_region():
    """A file sharing a large middle region with another must dedup chunks."""
    cdc = FastCDC(min_size=64, avg_size=256, max_size=1024)
    rng = np.random.RandomState(4)
    shared = rng.bytes(32 * 1024)
    f1 = rng.bytes(4096) + shared + rng.bytes(4096)
    f2 = rng.bytes(2048) + shared + rng.bytes(512)
    dd = ChunkDedup(cdc)
    dd.scan_bytes(f1)
    before = dd.stats.unique_bytes
    dd.scan_bytes(f2)
    added = dd.stats.unique_bytes - before
    assert added < len(f2) * 0.5  # most of f2 deduped against shared region


# ---------------------------------------------------------------------------
# Bit distance + clustering threshold (paper Eq. 1, §4.2)
# ---------------------------------------------------------------------------

def test_bit_distance_manual():
    a = np.array([0b0000, 0b1111], np.uint16)
    b = np.array([0b0001, 0b1111], np.uint16)
    assert bit_distance_arrays(a, b) == 0.5  # 1 differing bit over 2 elements


def test_mc_calibration_within_family_band():
    """Paper §4.2: σw∈[0.015,0.05], σΔ∈[0,0.02] ⇒ E[D] within [~1.5, 6]."""
    lo = expected_bit_distance_mc(0.05, 0.0005, n=20000)
    hi = expected_bit_distance_mc(0.015, 0.02, n=20000)
    assert 0.5 <= lo <= 6.0
    assert 2.5 <= hi <= 7.0
    # cross-family (independent draws) clearly exceeds the threshold of 4.
    # (the paper reports >6 on real models, whose per-tensor σw spread widens
    # exponent disagreement; equal-σ synthetic draws land ~5.7)
    import jax, jax.numpy as jnp
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w1 = (jax.random.normal(k1, (20000,)) * 0.02).astype(jnp.bfloat16)
    w2 = (jax.random.normal(k2, (20000,)) * 0.02).astype(jnp.bfloat16)
    from repro.kernels.ops import bit_distance
    assert bit_distance(w1, w2) > 4.5


def test_clustering_recovers_families(corpus_dir):
    from repro.core.clustering import cluster_models
    root, manifest = corpus_dir
    # full-weight repos only (LoRA adapters have different signatures anyway)
    paths, fams = [], []
    for rid, kind in manifest:
        if kind in ("base", "finetune", "reupload", "checkpoint"):
            paths.append(os.path.join(root, rid, "model.safetensors"))
            fams.append(rid.split("/")[0][-1] if kind == "base" else rid)
    comps = cluster_models(paths, threshold=4.0)
    # two families -> the two largest components must not mix base models
    assert len(comps) >= 2


# ---------------------------------------------------------------------------
# End-to-end pipeline
# ---------------------------------------------------------------------------

def test_pipeline_bitexact_and_synergy(tmp_path, corpus_dir):
    root, manifest = corpus_dir
    store = ZLLMStore(str(tmp_path / "store"))
    for rid, kind in manifest:
        store.ingest_repo(os.path.join(root, rid), rid)
    s = store.summary()
    assert s["reduction_ratio"] > 0.35          # dedup+BitX beats either alone
    assert store.stats.n_file_dedup >= 2        # re-uploads caught
    # every file reconstructs bit-exactly (verified against ingest hash inside)
    for rid, kind in manifest:
        orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
        assert store.retrieve_file(rid, "model.safetensors") == orig


def test_pipeline_vocab_expansion_fallback(tmp_path, corpus_dir):
    root, manifest = corpus_dir
    store = ZLLMStore(str(tmp_path / "store2"))
    for rid, kind in manifest:
        store.ingest_repo(os.path.join(root, rid), rid)
    exp = [r for r in store.results if "vocab" in r.repo_id]
    assert exp and all(r.n_zipnn >= 2 for r in exp)  # embed+lm_head shape-mismatch
    assert all(r.n_bitx > 0 for r in exp)            # remaining tensors still BitX


def test_pipeline_dedup_compression_ablation(tmp_path, corpus_dir):
    """The paper's core claim: dedup and compression are SYNERGISTIC."""
    root, manifest = corpus_dir
    variants = {}
    for name, kw in [("full", {}),
                     ("no_dedup", {"use_tensor_dedup": False}),
                     ("no_bitx", {"use_bitx": False})]:
        s = ZLLMStore(str(tmp_path / f"store_{name}"), **kw)
        for rid, kind in manifest:
            s.ingest_repo(os.path.join(root, rid), rid)
        variants[name] = s.summary()["reduction_ratio"]
        # losslessness holds in every configuration
        for rid, kind in manifest[:4]:
            orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
            assert s.retrieve_file(rid, "model.safetensors") == orig
    assert variants["full"] > variants["no_dedup"]
    assert variants["full"] > variants["no_bitx"]


def test_store_index_persistence(tmp_path, corpus_dir):
    """A reopened store serves retrievals and continues ingesting (dedup +
    family state intact across processes)."""
    root, manifest = corpus_dir
    s1 = ZLLMStore(str(tmp_path / "pstore"))
    half = len(manifest) // 2
    for rid, kind in manifest[:half]:
        s1.ingest_repo(os.path.join(root, rid), rid)
    s1.save_index()

    s2 = ZLLMStore(str(tmp_path / "pstore"))
    assert s2.load_index()
    # retrieval of pre-restart files works bit-exactly
    rid0 = manifest[0][0]
    orig = open(os.path.join(root, rid0, "model.safetensors"), "rb").read()
    assert s2.retrieve_file(rid0, "model.safetensors") == orig
    # continued ingest still finds cross-restart dedup + family matches
    for rid, kind in manifest[half:]:
        s2.ingest_repo(os.path.join(root, rid), rid)
    post = [r for r in s2.results if r.base_id or r.file_dedup_hit or r.n_dedup]
    assert post, "no cross-restart dedup/family reuse found"
    for rid, kind in manifest[half:]:
        orig = open(os.path.join(root, rid, "model.safetensors"), "rb").read()
        assert s2.retrieve_file(rid, "model.safetensors") == orig
