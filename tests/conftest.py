import os
import sys

# the dry-run forces 512 host devices in its own subprocesses; tests must see
# the default single CPU device
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
# tests import sibling helpers (_hypothesis_compat) without a package prefix
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def corpus_dir(tmp_path_factory):
    """Small synthetic hub corpus shared across tests."""
    from benchmarks.corpus import CorpusSpec, make_corpus
    root = str(tmp_path_factory.mktemp("hub"))
    # quantized_per_family=1 puts one int8 repack per family in the shared
    # corpus, so every store-level suite (persistence, parallel determinism,
    # backend equivalence) exercises the bitxq dtype-crossing lane for free
    spec = CorpusSpec(n_families=2, finetunes_per_family=2, lora_per_family=1,
                      vocab_expanded_per_family=1, checkpoints_per_family=1,
                      quantized_per_family=1,
                      n_layers=2, d_model=64, d_ff=128, vocab=256, seed=7)
    manifest = make_corpus(root, spec)
    return root, manifest
