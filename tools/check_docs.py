"""Documentation link/anchor checker (the CI docs gate).

Walks every tracked markdown file (``docs/*.md`` plus the repo's
``README.md`` files), and fails on:

* relative links whose target file does not exist;
* intra- and cross-file ``#anchor`` fragments that match no heading in
  the target markdown file (GitHub slug rules, approximated);
* ``src/...:<line>`` source anchors whose file is missing or shorter
  than the referenced line (the ARCHITECTURE doc pins prose to code —
  a shrunken file means the anchor rotted).

External ``http(s)://`` / ``mailto:`` links are skipped (no network in
CI). Exit status 0 = clean, 1 = dangling references (listed on stderr).

    python tools/check_docs.py [repo_root]
"""

from __future__ import annotations

import os
import re
import sys
from typing import Dict, List, Set

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
IMAGE_LINK_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
# `src/repro/core/pipeline.py:123`-style anchors in prose/code spans
SRC_ANCHOR_RE = re.compile(r"`((?:src|tests|benchmarks|examples|tools)"
                           r"/[\w./\-]+?):(\d+)`")
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", "node_modules",
             ".claude"}


def _slug(heading: str) -> str:
    """Approximate GitHub's heading -> anchor slug."""
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _headings(path: str) -> Set[str]:
    slugs: Dict[str, int] = {}
    out: Set[str] = set()
    in_code = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if line.lstrip().startswith("```"):
                in_code = not in_code
                continue
            if in_code:
                continue
            m = HEADING_RE.match(line)
            if m:
                s = _slug(m.group(1))
                n = slugs.get(s, 0)
                slugs[s] = n + 1
                out.add(s if n == 0 else f"{s}-{n}")
    return out


def markdown_files(root: str) -> List[str]:
    out = []
    for dirpath, dirnames, files in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for fn in files:
            if not fn.endswith(".md"):
                continue
            rel = os.path.relpath(os.path.join(dirpath, fn), root)
            if rel.startswith("docs" + os.sep) or fn == "README.md":
                out.append(os.path.join(dirpath, fn))
    return sorted(out)


def check_file(path: str, root: str, heading_cache: Dict[str, Set[str]]) -> List[str]:
    errors: List[str] = []
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(path)

    def headings_of(p: str) -> Set[str]:
        p = os.path.abspath(p)
        if p not in heading_cache:
            heading_cache[p] = _headings(p)
        return heading_cache[p]

    for m in list(LINK_RE.finditer(text)) + list(IMAGE_LINK_RE.finditer(text)):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target, _, frag = target.partition("#")
        if target:
            tpath = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(tpath):
                errors.append(f"{os.path.relpath(path, root)}: dangling link "
                              f"target {target!r}")
                continue
        else:
            tpath = path  # same-file anchor
        if frag and tpath.endswith(".md"):
            if frag not in headings_of(tpath):
                errors.append(f"{os.path.relpath(path, root)}: dangling "
                              f"anchor #{frag} in {os.path.relpath(tpath, root)}")

    for m in SRC_ANCHOR_RE.finditer(text):
        spath, line = m.group(1), int(m.group(2))
        fpath = os.path.join(root, spath)
        if not os.path.exists(fpath):
            errors.append(f"{os.path.relpath(path, root)}: source anchor "
                          f"{spath}:{line} — file missing")
            continue
        n_lines = sum(1 for _ in open(fpath, encoding="utf-8",
                                      errors="replace"))
        if line > n_lines:
            errors.append(f"{os.path.relpath(path, root)}: source anchor "
                          f"{spath}:{line} past EOF ({n_lines} lines)")
    return errors


def run(root: str) -> List[str]:
    heading_cache: Dict[str, Set[str]] = {}
    errors: List[str] = []
    files = markdown_files(root)
    for path in files:
        errors.extend(check_file(path, root, heading_cache))
    print(f"check_docs: scanned {len(files)} markdown file(s), "
          f"{len(errors)} dangling reference(s)")
    return errors


def main(argv=None) -> int:
    root = (argv or sys.argv[1:] or ["."])[0]
    root = os.path.abspath(root)
    errors = run(root)
    for e in errors:
        print(f"check_docs: FAIL {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
