"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — ``jax.make_mesh`` is only called by the
dry-run driver (which forces 512 host devices) or by tests (which build tiny
local meshes).

Production topology (TPU v5e-like):

* single-pod: 16 × 16 = 256 chips, axes ("data", "model")
* multi-pod:  2 × 16 × 16 = 512 chips, axes ("pod", "data", "model")

The "model" axis carries TP + sequence-parallel decode; "data" carries DP +
FSDP; "pod" carries DP (and optionally FSDP for grok-scale models — see
``ShardingRules.for_mesh(fsdp_over_pod=True)``).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def _mk(shape, axes):
    # jax >= 0.4.35 exposes AxisType; older releases (this container ships
    # 0.4.x without it) accept plain make_mesh with default axis types
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many devices the test process has."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


class HW:
    """TPU v5e-like hardware constants for the roofline model."""

    PEAK_FLOPS_BF16 = 197e12     # per chip
    HBM_BW = 819e9               # bytes/s per chip
    ICI_BW_PER_LINK = 50e9       # bytes/s per link (~)
    HBM_BYTES = 16 * 2**30       # 16 GiB per chip
