import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**specs).compile()`` must succeed on the
single-pod 16×16 mesh AND the 2×16×16 multi-pod mesh for every assigned
architecture × its applicable input shapes. The compiled artifact yields the
roofline inputs: ``cost_analysis()`` (FLOPs / HBM bytes per device),
``memory_analysis()`` (fits-in-HBM proof), and the partitioned HLO text
(collective traffic, parsed by ``hlo_analysis``).

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
    python -m repro.launch.dryrun --arch ... --variant remat=dots,grad=bfloat16

``--all`` drives one subprocess per cell (isolated XLA state, resumable: cells
with an existing JSON record are skipped unless --force). Results land in
experiments/dryrun/<arch>__<shape>__<mesh>[__<variant>].json.
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _cell_fname(arch: str, shape: str, mesh: str, variant: str = "") -> str:
    base = f"{arch}__{shape}__{mesh}"
    if variant:
        base += "__" + variant.replace("=", "-").replace(",", "_")
    return base + ".json"


# ---------------------------------------------------------------------------
# Single-cell execution (runs inside the subprocess)
# ---------------------------------------------------------------------------

def build_step(cfg, cell, mesh, rules, *, remat_policy="nothing", grad_dtype="float32"):
    """Returns (fn, arg_sds tuple, in_shardings tuple, out_shardings)."""
    import jax
    from repro.models.api import (abstract_cache, abstract_inputs, abstract_params,
                                  cache_shardings, get_model, input_shardings,
                                  param_shardings)
    from repro.optim.optimizers import OptimizerConfig, make_optimizer
    from repro.sharding.rules import spec_tree_sds, spec_tree_shardings
    from repro.train.step import make_train_step

    model = get_model(cfg, mesh, rules, remat_policy=remat_policy)
    p_sds = abstract_params(cfg)
    p_sh = param_shardings(cfg, mesh, rules)
    i_sds = abstract_inputs(cfg, cell)
    i_sh = input_shardings(cfg, cell, mesh, rules)

    if cell.kind == "train":
        opt = make_optimizer(OptimizerConfig(name=cfg.optimizer))
        o_tmpl = opt.state_templates(model.param_templates())
        o_sds = spec_tree_sds(o_tmpl)
        o_sh = spec_tree_shardings(o_tmpl, mesh, rules)
        step = make_train_step(model, opt, microbatches=cell.microbatches,
                               grad_dtype=grad_dtype)
        return step, (p_sds, o_sds, i_sds), (p_sh, o_sh, i_sh), (p_sh, o_sh, None)

    if cell.kind == "prefill":
        def step(params, batch):
            return model.prefill(params, batch)
        c_sh = cache_shardings(cfg, cell.global_batch, cell.seq_len, mesh, rules)
        return step, (p_sds, i_sds), (p_sh, i_sh), (None, c_sh)

    if cell.kind == "decode":
        def step(params, batch, cache):
            return model.decode_step(params, batch, cache)
        c_sds = abstract_cache(cfg, cell.global_batch, cell.seq_len)
        c_sh = cache_shardings(cfg, cell.global_batch, cell.seq_len, mesh, rules)
        return step, (p_sds, i_sds, c_sds), (p_sh, i_sh, c_sh), (None, c_sh)

    raise ValueError(cell.kind)


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path,
             variant: str = "") -> dict:
    import jax
    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.hlo_analysis import count_op_kinds
    from repro.launch.hlo_cost import analyze_module
    from repro.launch.mesh import make_production_mesh
    from repro.sharding.rules import ShardingRules

    opts = dict(kv.split("=") for kv in variant.split(",") if kv)
    remat_policy = opts.get("remat", "nothing")
    grad_dtype = opts.get("grad", "float32")

    cfg = get_config(arch)
    if "attn" in opts:
        cfg = dataclasses.replace(cfg, attn_score_dtype=opts["attn"])
    if "cq" in opts:
        cfg = dataclasses.replace(cfg, attn_chunk_q=int(opts["cq"]))
    if "ck" in opts:
        cfg = dataclasses.replace(cfg, attn_chunk_kv=int(opts["ck"]))
    if "mb" in opts:
        cell = None  # placeholder, reassigned below
    cell = SHAPES_BY_NAME[shape]
    if "mb" in opts:
        cell = cell.with_microbatches(int(opts["mb"]))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = ShardingRules.for_mesh(mesh, fsdp_over_pod=cfg.fsdp_over_pod)
    n_chips = mesh.size

    fn, sds, in_sh, out_sh = build_step(cfg, cell, mesh, rules,
                                        remat_policy=remat_policy, grad_dtype=grad_dtype)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*sds)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
    hlo = compiled.as_text()
    hcost = analyze_module(hlo)

    from repro.models.api import get_model
    model = get_model(cfg)
    N, Na = model.param_count(), model.active_param_count()
    tokens = cell.global_batch * (cell.seq_len if cell.kind == "train" else
                                  (cell.seq_len if cell.kind == "prefill" else 1))
    model_flops = (6 if cell.kind == "train" else 2) * Na * tokens

    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "variant": variant,
        "chips": n_chips,
        "param_count": int(N),
        "active_param_count": int(Na),
        "tokens_per_step": int(tokens),
        "model_flops_global": float(model_flops),
        # trip-count-aware per-device costs from the partitioned HLO
        "flops_per_device": float(hcost.flops),
        "dot_flops_per_device": float(hcost.dot_flops),
        "bytes_per_device": float(hcost.bytes),
        "transcendentals_per_device": float(hcost.transcendentals),
        # raw cost_analysis for reference (counts while bodies ONCE)
        "xla_flops_raw": float(cost.get("flops", 0.0)),
        "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        "memory_analysis": mem_d,
        "collectives": hcost.summary(),
        "op_census": count_op_kinds(hlo),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_lines": hlo.count("\n"),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / _cell_fname(arch, shape, mesh_kind, variant)
    out_path.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] OK {arch} {shape} {mesh_kind} {variant or '-'} | "
          f"compile {t_compile:.1f}s | flops/dev {rec['flops_per_device']:.3e} | "
          f"bytes/dev {rec['bytes_per_device']:.3e} | "
          f"coll {hcost.collective_total:.3e}B | temp {mem_d.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    return rec


# ---------------------------------------------------------------------------
# Sweep driver (spawns one subprocess per cell)
# ---------------------------------------------------------------------------

def all_cells(mesh_kind: str):
    from repro.configs import ARCH_IDS, cells_for, get_config
    meshes = ["single", "multi"] if mesh_kind == "both" else [mesh_kind]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in cells_for(cfg):
            for mk in meshes:
                yield arch, cell.name, mk


def drive_all(mesh_kind: str, out_dir: Path, force: bool, variant: str = "",
              timeout: int = 7200) -> int:
    todo = list(all_cells(mesh_kind))
    failed = []
    for i, (arch, shape, mk) in enumerate(todo):
        out_path = out_dir / _cell_fname(arch, shape, mk, variant)
        if out_path.exists() and not force:
            print(f"[dryrun] skip {arch} {shape} {mk} (cached)")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mk, "--out", str(out_dir)]
        if variant:
            cmd += ["--variant", variant]
        print(f"[dryrun] ({i+1}/{len(todo)}) {' '.join(cmd[3:])}", flush=True)
        r = subprocess.run(cmd, timeout=timeout)
        if r.returncode != 0:
            failed.append((arch, shape, mk))
            print(f"[dryrun] FAIL {arch} {shape} {mk}", flush=True)
    if failed:
        print(f"[dryrun] {len(failed)} FAILURES: {failed}")
        return 1
    print(f"[dryrun] sweep complete: {len(todo)} cells")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="", help="e.g. remat=dots,grad=bfloat16")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        sys.exit(drive_all(args.mesh, out_dir, args.force, args.variant))
    assert args.arch and args.shape and args.mesh != "both"
    run_cell(args.arch, args.shape, args.mesh, out_dir, args.variant)


if __name__ == "__main__":
    main()
