"""Roofline analysis over the dry-run artifacts (assignment deliverable g).

Reads experiments/dryrun/*.json (written by ``repro.launch.dryrun``) and
derives, per (arch × shape × mesh):

    compute term    = dot_FLOPs_per_device / 197 TFLOP/s        (MXU)
    memory term     = HBM_bytes_per_device / 819 GB/s
    collective term = collective_bytes_per_device / 50 GB/s     (per-link ICI)

plus: the dominant term, MODEL_FLOPS = 6·N_active·tokens (train) or
2·N_active·tokens (prefill/decode), the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs·chips), and a roofline fraction

    RF = [MODEL_FLOPS / (chips · peak)] / max(terms)

— the fraction of the step's resource-bound lower-bound time that is useful
model compute (1.0 = the useful compute fully saturates the binding
resource). All numerators are per-device (shapes in partitioned HLO are shard
shapes); collective bytes assume one active ICI link per device
(conservative: a 2D torus can stripe 2-4 links, noted in EXPERIMENTS.md).

Usage: python -m repro.launch.roofline [--dir experiments/dryrun] [--md out.md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.launch.mesh import HW

__all__ = ["load_records", "roofline_row", "render_table"]

DEFAULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

LONG_SKIPS = {
    "qwen2-vl-7b": "full attention (M-RoPE), quadratic",
    "granite-20b": "full attention, quadratic",
    "phi4-mini-3.8b": "full attention, quadratic",
    "deepseek-coder-33b": "full attention, quadratic",
    "qwen2-7b": "full attention, quadratic",
    "grok-1-314b": "full attention, quadratic",
    "whisper-medium": "full attention, quadratic",
}


def load_records(d: Path, variant: str = "") -> List[Dict]:
    out = []
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("variant", "") == variant:
            out.append(rec)
    return out


def roofline_row(rec: Dict) -> Dict:
    chips = rec["chips"]
    compute_s = rec.get("dot_flops_per_device", rec["flops_per_device"]) / HW.PEAK_FLOPS_BF16
    memory_s = rec["bytes_per_device"] / HW.HBM_BW
    coll_s = rec["collectives"]["collective_total_bytes"] / HW.ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    t_model = rec["model_flops_global"] / (chips * HW.PEAK_FLOPS_BF16)
    rf = t_model / bound if bound > 0 else 0.0
    rf_compute = t_model / compute_s if compute_s > 0 else 0.0
    useful = rec["model_flops_global"] / max(rec["flops_per_device"] * chips, 1e-9)
    mxu_useful = rec["model_flops_global"] / max(
        rec.get("dot_flops_per_device", 0.0) * chips, 1e-9)
    hbm_gib = rec.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 2**30
    recommend = {
        "compute": "cut recomputation (remat policy) / reduce non-model FLOPs",
        "memory": "raise arithmetic intensity: larger microbatch, fuse, avoid fp32 spills",
        "collective": "overlap or shrink collectives: bf16 grads, better layout, fewer reshards",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": rec["model_flops_global"],
        "useful_ratio": useful,          # MODEL_FLOPS / total HLO flops (all devices)
        "mxu_useful_ratio": mxu_useful,  # MODEL_FLOPS / dot flops only
        "roofline_fraction": rf,
        "rf_compute": rf_compute,   # MFU proxy: useful / total MXU time
        "temp_GiB": hbm_gib,
        "recommend": recommend,
        "compile_s": rec.get("compile_s"),
    }


def render_table(rows: List[Dict], title: str = "Roofline (single-pod 16×16)") -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | dominant "
           "| RF | RFc | 6ND/HLO | temp GiB | next lever |")
    sep = "|" + "---|" * 12
    lines = [f"### {title}", "", hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} | {r['rf_compute']:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['temp_GiB']:.1f} | {r['recommend']} |")
    lines.append("")
    lines.append("Skipped long_500k cells (quadratic attention, per assignment):")
    for a, why in LONG_SKIPS.items():
        lines.append(f"- {a}: {why}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(DEFAULT_DIR))
    ap.add_argument("--variant", default="")
    ap.add_argument("--md", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    args = ap.parse_args()
    recs = load_records(Path(args.dir), args.variant)
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    rows = [roofline_row(r) for r in recs]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    table = render_table(rows)
    print(table)
    if args.md:
        Path(args.md).write_text(table)


if __name__ == "__main__":
    main()
