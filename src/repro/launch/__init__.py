"""launch subsystem."""
