"""Post-partitioning HLO analysis: collective-traffic accounting.

``cost_analysis()`` reports FLOPs and HBM bytes but NOT collective traffic, so
we parse the compiled (SPMD-partitioned) HLO text and sum operand bytes of
every communication op:

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute

Shapes in post-partitioning HLO are *per-device shard* shapes, so the sums are
per-device collective bytes — exactly the numerator of the roofline collective
term. Async pairs (``all-gather-start``/``-done``) are counted once at start.

We also count replica-group fan-out per op (axis size of the collective) so
the roofline can model ring-bandwidth factors ((n-1)/n for all-gather etc.).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

__all__ = ["CollectiveStats", "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one tensor literal: dtype[dims]{layout}  (layout optional, dims optional for scalars)
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\((.*)$"
)
_DONE_RE = re.compile(r"(" + "|".join(_COLLECTIVES) + r")-done\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    """Per-device collective byte totals by op kind."""

    op_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    op_count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    ops: List[Tuple[str, int, int]] = field(default_factory=list)  # (kind, bytes, group)

    @property
    def total_bytes(self) -> int:
        return sum(self.op_bytes.values())

    def summary(self) -> Dict:
        return {
            "total_bytes": self.total_bytes,
            "by_op": {k: int(v) for k, v in sorted(self.op_bytes.items())},
            "counts": {k: int(v) for k, v in sorted(self.op_count.items())},
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line and _DONE_RE.search(line):
            continue  # async completion — counted at start
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand bytes: shapes listed inside the call parens
        operand_text = m.group(2)
        nbytes = _shape_bytes(operand_text)
        if nbytes == 0:
            # operands printed without shapes (short form) — fall back to the
            # result shape on the lhs of '='
            lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(kind)[0]
            nbytes = _shape_bytes(lhs)
        group = 0
        g = _GROUPS_RE.search(line)
        if g:
            group = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                group = int(g2.group(2))
        stats.op_bytes[kind] += nbytes
        stats.op_count[kind] += 1
        stats.ops.append((kind, nbytes, group))
    return stats


def count_op_kinds(hlo_text: str, prefixes=("fusion", "dot", "convolution", "scatter",
                                            "gather", "sort", "while")) -> Dict[str, int]:
    """Rough op-kind census of a compiled module (perf-iteration diagnostics)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        for p in prefixes:
            if re.search(r"\b" + p + r"\(", rhs):
                counts[p] += 1
    return dict(counts)
