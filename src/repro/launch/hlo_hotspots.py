"""Hotspot descent over compiled HLO — the dry-run "profiler" (§Perf loop).

With no real TPU to trace, the perf iteration reasons from the compiled
artifact: this tool attributes the trip-count-aware cost model's bytes/FLOPs
to individual instructions and recursively descends into the dominant while
loop, printing the top contributors at each level — the closest thing to a
flame graph the dry-run can give.

Usage:
    python -m repro.launch.hlo_hotspots --arch qwen2-7b --shape train_4k \
        [--mesh single] [--metric bytes|flops] [--top 5]
"""

from __future__ import annotations

import argparse
import re
from typing import Dict, List

from repro.launch import hlo_cost as H

__all__ = ["hotspots", "descend"]


def _metric(c: "H.HloCost", name: str) -> float:
    if name == "coll":
        return c.collective_total
    return getattr(c, name)


def _instr_cost(an: "H._Analyzer", comp: str, i: "H.Instr") -> "H.HloCost":
    one = H._Analyzer.__new__(H._Analyzer)
    one.comps = dict(an.comps)
    one.tables = dict(an.tables)
    one.params, one.consumers, one.roots = an.params, an.consumers, an.roots
    one.memo = dict(an.memo)
    one.comps["__one"] = [i]
    one.tables["__one"] = an.tables[comp]
    return one.cost("__one")


def descend(comps: Dict, an: "H._Analyzer", comp: str, *, metric: str = "bytes",
            top: int = 5, depth: int = 0, mult: float = 1.0,
            max_depth: int = 8, out: List[str] = None) -> List[str]:
    out = out if out is not None else []
    rows = []
    for i in comps.get(comp, []):
        c = _instr_cost(an, comp, i)
        rows.append((_metric(c, metric), c, i))
    rows.sort(key=lambda r: -r[0])
    for val, c, i in rows[:top]:
        out.append("  " * depth + f"{val * mult:.3e} {metric}  {i.opcode:18s} "
                   f"{i.line.strip()[:110]}")
    if rows and rows[0][2].opcode == "while" and depth < max_depth:
        topi = rows[0][2]
        bm = re.search(r"body=%?([\w\.\-]+)", topi.line)
        cm = re.search(r"condition=%?([\w\.\-]+)", topi.line)
        if bm and cm:
            trips = an.trip_count(cm.group(1)) or 1
            out.append("  " * depth + f"--> {bm.group(1)} × {trips}")
            descend(comps, an, bm.group(1), metric=metric, top=top,
                    depth=depth + 1, mult=mult * trips, max_depth=max_depth, out=out)
    return out


def hotspots(hlo_text: str, metric: str = "bytes", top: int = 5) -> str:
    comps, entry = H.parse_module(hlo_text)
    an = H._Analyzer(comps)
    an.cost(entry)
    return "\n".join(descend(comps, an, entry, metric=metric, top=top))


def main():
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.dryrun import build_step
    from repro.launch.mesh import make_production_mesh
    from repro.sharding.rules import ShardingRules

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--metric", default="bytes", choices=["bytes", "flops", "coll"])
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    opts = dict(kv.split("=") for kv in args.variant.split(",") if kv)
    cfg = get_config(args.arch)
    cell = SHAPES_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    rules = ShardingRules.for_mesh(mesh, fsdp_over_pod=cfg.fsdp_over_pod)
    fn, sds, in_sh, out_sh = build_step(
        cfg, cell, mesh, rules, remat_policy=opts.get("remat", "nothing"),
        grad_dtype=opts.get("grad", "float32"))
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*sds).compile()
    print(hotspots(compiled.as_text(), args.metric, args.top))


if __name__ == "__main__":
    main()
