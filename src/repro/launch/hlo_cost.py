"""Trip-count-aware cost model over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — a step that
scans over 62 layers × 8 microbatches under-reports FLOPs by ~500×. This
module re-derives the roofline numerators from the HLO text itself:

* parse every computation into (result shape, opcode, operands, attrs),
* cost instructions bottom-up: dots/convs get exact FLOPs from contraction
  dims, elementwise ops count one FLOP per output element, fusions charge
  HBM bytes only at their boundary (XLA's own convention),
* ``while`` multiplies its body+condition cost by the trip count recovered
  from the loop condition (`compare(induction, constant(N)), direction=LT`),
* collectives (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute) accumulate OPERAND bytes — shard-local, so the totals
  are per-device — scaled by enclosing trip counts; async start/done pairs
  count once.

Shapes in post-partitioning HLO are per-device shard shapes, so every number
this module produces is per-device.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_module", "parse_module"]

DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "tf32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_LIT = re.compile(
    r"\b(pred|bf16|f16|f32|f64|tf32|s2|u2|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|token)\[([0-9,]*)\]"
)
# instruction line: [ROOT] %name = <shape-ish> opcode(operands...) , attrs
_INSTR = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HEADER = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPCODE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_CALLED = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_FEATURE_GROUPS = re.compile(r"feature_group_count=(\d+)")
_DIM_LABELS = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sine", "cosine", "tan", "sqrt", "rsqrt", "cbrt", "power",
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "compare", "select", "clamp", "atan2",
    "remainder", "popcnt", "count-leading-zeros", "erf",
}
_REDUCE_LIKE = {"reduce", "reduce-window"}
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
         "opt-barrier", "custom-call", "get-dimension-size"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_info(text: str) -> Tuple[int, int]:
    """(total bytes, total elements) across all shape literals in ``text``."""
    nbytes = 0
    elems = 0
    for dt, dims in _SHAPE_LIT.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        nbytes += n * DTYPE_BYTES[dt]
        elems += n
    return nbytes, elems


def _first_shape_dims(text: str) -> List[int]:
    m = _SHAPE_LIT.search(text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_elems: int
    result_dims: List[int]
    operands: List[str]
    line: str
    const_int: Optional[int] = None
    is_root: bool = False


@dataclass
class HloCost:
    flops: float = 0.0
    dot_flops: float = 0.0       # dot + convolution only (MXU work)
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    unknown_loops: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += v * mult
        self.unknown_loops += other.unknown_loops

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())

    def summary(self) -> Dict:
        return {
            "flops": self.flops,
            "dot_flops": self.dot_flops,
            "bytes": self.bytes,
            "transcendentals": self.transcendentals,
            "collective_bytes": {k: v for k, v in sorted(self.coll_bytes.items())},
            "collective_counts": {k: v for k, v in sorted(self.coll_count.items())},
            "collective_total_bytes": self.collective_total,
            "unknown_loops": self.unknown_loops,
        }


def parse_module(text: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    """Split HLO text into computations. Returns ({comp_name: instrs}, entry)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[List[Instr]] = None
    cur_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m and "{" in line:
                cur_name = m.group(1)
                cur = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur_name
            continue
        if line.strip() == "}":
            comps[cur_name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        rhs = m.group(3)
        op_m = _OPCODE.search(rhs)
        if not op_m:
            continue
        # shape is everything before the opcode
        shape_txt = rhs[: op_m.start()]
        opcode = op_m.group(1)
        nbytes, elems = _shape_info(shape_txt)
        dims = _first_shape_dims(shape_txt)
        # operands: names inside the first (...) after the opcode
        paren = rhs[op_m.end():]
        depth = 1
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_txt = paren[:end]
        operands = _OPERAND_NAME.findall(operand_txt)
        ci = None
        cm = _CONST_INT.search(rhs)
        if cm and opcode == "constant":
            ci = int(cm.group(1))
        elif opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", rhs)
            if pm:
                ci = int(pm.group(1))
        cur.append(Instr(m.group(2), opcode, nbytes, elems, dims, operands,
                         line, ci, bool(m.group(1))))
    if cur is not None and cur_name:
        comps[cur_name] = cur
    return comps, entry


_SLICE_READS = ("dynamic-slice", "slice", "gather")


class _Analyzer:
    def __init__(self, comps: Dict[str, List[Instr]]):
        self.comps = comps
        self.memo: Dict[str, HloCost] = {}
        self.tables: Dict[str, Dict[str, Instr]] = {
            name: {i.name: i for i in instrs} for name, instrs in comps.items()
        }
        # per computation: parameter index -> Instr, consumers, root
        self.params: Dict[str, Dict[int, Instr]] = {}
        self.consumers: Dict[str, Dict[str, List[Instr]]] = {}
        self.roots: Dict[str, Optional[Instr]] = {}
        for name, instrs in comps.items():
            pm: Dict[int, Instr] = {}
            cons: Dict[str, List[Instr]] = {}
            root = instrs[-1] if instrs else None
            for i in instrs:
                if i.opcode == "parameter" and i.const_int is not None:
                    pm[i.const_int] = i
                if i.is_root:
                    root = i
                for op in i.operands:
                    cons.setdefault(op, []).append(i)
            self.params[name] = pm
            self.consumers[name] = cons
            self.roots[name] = root

    # -- byte model helpers ------------------------------------------------
    # HBM traffic follows TPU aliasing semantics: slicing reads only the
    # slice; dynamic-update-slice writes only the update region (the result
    # aliases its operand); a fusion operand that is ONLY sliced inside the
    # fused computation is charged at the sliced size — this is what keeps a
    # scan over stacked layer params O(L·layer) instead of O(L²·layer).

    def _see_through(self, instr: Optional[Instr], table) -> Optional[Instr]:
        """Follow bitcast/convert/copy/reshape chains back to the producer."""
        seen = 0
        while instr is not None and instr.opcode in ("bitcast", "convert",
                                                     "reshape", "copy") and seen < 8:
            if not instr.operands:
                break
            instr = table.get(instr.operands[0])
            seen += 1
        return instr

    def _write_bytes_of_root(self, root: Optional[Instr], comp: str) -> int:
        if root is None:
            return 0
        table = self.tables[comp]
        root = self._see_through(root, table) or root
        if root.opcode == "dynamic-update-slice":
            upd = table.get(root.operands[1]) if len(root.operands) > 1 else None
            return 2 * upd.result_bytes if upd is not None else root.result_bytes
        if root.opcode == "tuple":
            n = 0
            for op in root.operands:
                prod = self._see_through(table.get(op), table)
                if prod is not None and prod.opcode == "dynamic-update-slice":
                    upd = table.get(prod.operands[1]) if len(prod.operands) > 1 else None
                    n += 2 * upd.result_bytes if upd is not None else prod.result_bytes
                elif prod is not None:
                    n += prod.result_bytes
            return n
        return root.result_bytes

    def _fusion_bytes(self, i: Instr, table: Dict[str, Instr], comp: str) -> int:
        """Boundary bytes of a fusion: sliced operands charge sliced sizes;
        a DUS root charges the update region, not the whole buffer."""
        pm = self.params.get(comp, {})
        cons = self.consumers.get(comp, {})
        total = 0
        for idx, opname in enumerate(i.operands):
            ref = table.get(opname)
            full = ref.result_bytes if ref is not None else 0
            p = pm.get(idx)
            if p is not None:
                uses = cons.get(p.name, [])
                if uses and all(u.opcode in _SLICE_READS for u in uses):
                    total += min(full, sum(u.result_bytes for u in uses))
                    continue
            total += full
        total += self._write_bytes_of_root(self.roots.get(comp), comp)
        return total

    def trip_count(self, cond_name: str) -> Optional[int]:
        instrs = self.comps.get(cond_name, [])
        table = self.tables.get(cond_name, {})
        for i in instrs:
            if i.opcode == "compare" and "direction=LT" in i.line:
                for op in i.operands:
                    ref = table.get(op)
                    if ref is not None and ref.const_int is not None:
                        return ref.const_int
        # fallback: any integer constant in the condition
        consts = [i.const_int for i in instrs if i.const_int is not None]
        return max(consts) if consts else None

    def cost(self, comp_name: str) -> HloCost:
        if comp_name in self.memo:
            return self.memo[comp_name]
        total = HloCost()
        self.memo[comp_name] = total  # guards recursion
        table = self.tables.get(comp_name, {})
        for i in self.comps.get(comp_name, []):
            op = i.opcode
            line = i.line
            if op == "while":
                called = _CALLED.findall(line)
                body = cond = None
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = self.trip_count(cond) if cond else None
                if trips is None:
                    trips = 1
                    total.unknown_loops += 1
                inner = HloCost()
                if body:
                    inner.add(self.cost(body))
                if cond:
                    inner.add(self.cost(cond))
                total.add(inner, float(trips))
            elif op == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", line)
                if cm:
                    called = cm.group(1)
                    inner = self.cost(called)
                    # FLOPs from inside; HBM bytes only at the fusion boundary
                    total.flops += inner.flops
                    total.dot_flops += inner.dot_flops
                    total.transcendentals += inner.transcendentals
                    for k, v in inner.coll_bytes.items():
                        total.coll_bytes[k] += v
                    total.bytes += self._fusion_bytes(i, table, called)
                else:
                    total.bytes += i.result_bytes + self._operand_bytes(i, table)
            elif op == "conditional":
                bm = _BRANCHES.search(line)
                if bm:
                    branches = _OPERAND_NAME.findall(bm.group(1))
                    inner = HloCost()
                    for b in branches:  # upper bound: sum? use max flops branch
                        c = self.cost(b)
                        if c.flops >= inner.flops:
                            inner = c
                    total.add(inner)
                total.bytes += i.result_bytes
            elif op in ("call", "map", "sort"):
                cm = re.search(r"to_apply=%?([\w\.\-]+)", line)
                if cm:
                    total.add(self.cost(cm.group(1)))
                total.bytes += i.result_bytes + self._operand_bytes(i, table)
                if op == "sort":
                    n = max(i.result_elems, 2)
                    total.flops += n * math.log2(n)
            elif any(op == c or op == c + "-start" for c in _COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                ob = self._operand_bytes(i, table)
                if ob == 0:
                    ob = i.result_bytes
                total.coll_bytes[kind] += ob
                total.coll_count[kind] += 1
                total.bytes += ob + i.result_bytes
            elif op.endswith("-done"):
                continue
            elif op == "dot":
                contract = 1
                cmm = _CONTRACT.search(line)
                lhs = table.get(i.operands[0]) if i.operands else None
                if cmm and lhs is not None and lhs.result_dims:
                    for d in cmm.group(1).split(","):
                        if d != "":
                            contract *= lhs.result_dims[int(d)]
                total.flops += 2.0 * i.result_elems * contract
                total.dot_flops += 2.0 * i.result_elems * contract
                total.bytes += i.result_bytes + self._operand_bytes(i, table)
            elif op == "convolution":
                kern = table.get(i.operands[1]) if len(i.operands) > 1 else None
                work = 1
                if kern is not None and kern.result_dims:
                    kern_elems = 1
                    for d in kern.result_dims:
                        kern_elems *= d
                    out_features = 1
                    dl = _DIM_LABELS.search(line)
                    if dl:
                        kl = dl.group(2)
                        if "o" in kl:
                            out_features = kern.result_dims[kl.index("o")]
                    work = max(1, kern_elems // max(out_features, 1))
                total.flops += 2.0 * i.result_elems * work
                total.dot_flops += 2.0 * i.result_elems * work
                total.bytes += i.result_bytes + self._operand_bytes(i, table)
            elif op in _REDUCE_LIKE:
                ob = self._operand_bytes(i, table)
                oe = self._operand_elems(i, table)
                total.flops += oe
                total.bytes += i.result_bytes + ob
            elif op in _ELEMENTWISE:
                total.flops += i.result_elems
                if op in ("exponential", "log", "tanh", "logistic", "sine",
                          "cosine", "rsqrt", "sqrt", "power", "erf"):
                    total.transcendentals += i.result_elems
                total.bytes += i.result_bytes + self._operand_bytes(i, table)
            elif op in _FREE:
                continue
            elif op in ("dynamic-slice", "slice", "gather"):
                total.bytes += 2 * i.result_bytes      # read slice + write
            elif op == "dynamic-update-slice":
                upd = table.get(i.operands[1]) if len(i.operands) > 1 else None
                total.bytes += 2 * (upd.result_bytes if upd is not None
                                    else i.result_bytes)
            elif op == "scatter":
                upd = table.get(i.operands[-1]) if i.operands else None
                total.bytes += 2 * (upd.result_bytes if upd is not None
                                    else i.result_bytes)
            elif op == "reshape":
                continue                               # layout-preserving view
            else:
                # copy, broadcast, transpose, concatenate, pad, convert,
                # select-and-scatter, ...
                total.bytes += i.result_bytes + self._operand_bytes(i, table)
        self.memo[comp_name] = total
        return total

    def _operand_bytes(self, i: Instr, table: Dict[str, Instr]) -> int:
        n = 0
        for op in i.operands:
            ref = table.get(op)
            if ref is not None:
                n += ref.result_bytes
        return n

    def _operand_elems(self, i: Instr, table: Dict[str, Instr]) -> int:
        n = 0
        for op in i.operands:
            ref = table.get(op)
            if ref is not None:
                n += ref.result_elems
        return n


def analyze_module(hlo_text: str) -> HloCost:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        # take the largest computation as entry fallback
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    if entry is None:
        return HloCost()
    return _Analyzer(comps).cost(entry)
