"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 50 --seq 128 --global-batch 8 [--mesh 2,2] \
        [--store /tmp/run-store] [--resume] [--fail-at 25]

Runs the fault-tolerant trainer on the local devices (CPU here; the same
code path drives TPU slices — the mesh shape argument maps onto whatever
`jax.devices()` provides). `--smoke` selects the reduced config of the same
family; full configs are for real accelerators. Checkpoints go through the
zLLM store when --store is given.
"""

from __future__ import annotations

import argparse
import json
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--run-dir", default="/tmp/repro-train-run")
    ap.add_argument("--store", default=None, help="zLLM store root for checkpoints")
    ap.add_argument("--mesh", default=None, help="data,model (e.g. 4,2)")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--remat", default="nothing", choices=["nothing", "dots", "none"])
    ap.add_argument("--grad-dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated crash at this step (fault-tolerance demo)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.pipeline import ZLLMStore
    from repro.optim.optimizers import OptimizerConfig
    from repro.train.trainer import (FailureInjector, SimulatedFailure,
                                     TrainConfig, Trainer)

    arch = get_config(args.arch, smoke=args.smoke)
    mesh_shape = tuple(int(x) for x in args.mesh.split(",")) if args.mesh else None
    store = ZLLMStore(args.store) if args.store else None

    cfg = TrainConfig(
        arch=arch, seq_len=args.seq, global_batch=args.global_batch,
        microbatches=args.microbatches, steps=args.steps,
        ckpt_every=args.ckpt_every, run_dir=args.run_dir,
        resume=not args.no_resume, grad_dtype=args.grad_dtype,
        remat_policy=args.remat, mesh_shape=mesh_shape,
        optimizer=OptimizerConfig(name=arch.optimizer, lr=args.lr,
                                  total_steps=args.steps),
    )
    trainer = Trainer(cfg, store=store, run_id=f"{arch.name}-run",
                      failure=FailureInjector(fail_at_step=args.fail_at))
    if trainer.resumed_from is not None:
        print(f"[train] resumed from step {trainer.resumed_from}")
    try:
        hist = trainer.run()
    except SimulatedFailure as e:
        print(f"[train] {e} — restart with the same command to resume")
        sys.exit(42)
    for h in hist[:: max(1, len(hist) // 10)]:
        print(f"[train] step {h['step']:>6} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.3f} {h['sec']*1e3:.0f} ms")
    print(f"[train] done: {len(hist)} steps, final loss {hist[-1]['loss']:.4f}")
    if store is not None:
        print(f"[train] store: {json.dumps(store.summary())}")


if __name__ == "__main__":
    main()
