"""optim subsystem."""
