"""Optimizers built in-tree (no optax): AdamW and Adafactor, with
warmup-cosine schedules and global-norm clipping.

Both optimizers expose ``state_templates`` so the dry-run can lower a full
``train_step`` (params + optimizer state as sharded ShapeDtypeStructs) without
allocating anything. Optimizer moments shard exactly like their parameters
(ZeRO semantics); Adafactor's factored second moment drops the last/second-to-
last dims (the reason grok-1-314b fits: 316B × 4-byte Adam moments would not).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamSpec

__all__ = ["OptimizerConfig", "make_optimizer", "AdamW", "Adafactor",
           "warmup_cosine", "global_norm", "clip_by_global_norm"]


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 128    # factor only dims >= this


def warmup_cosine(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)
    return sched


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamW:
    """Decoupled weight decay Adam; fp32 moments regardless of param dtype."""

    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg
        self.sched = warmup_cosine(cfg)

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def state_templates(self, templates: Dict[str, ParamSpec]) -> Dict[str, Dict]:
        f32 = {k: ParamSpec(v.shape, "float32", v.axes, stacked=v.stacked)
               for k, v in templates.items()}
        return {"m": f32, "v": dict(f32), "step": ParamSpec((), "int32", ())}

    def update(self, grads, state, params):
        c = self.cfg
        step = state["step"] + 1
        lr = self.sched(step)
        t = step.astype(jnp.float32)
        bc1 = 1 - c.b1 ** t
        bc2 = 1 - c.b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = c.b1 * m + (1 - c.b1) * g
            v = c.b2 * v + (1 - c.b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + c.eps)
            if p.ndim >= 2:  # no decay on norms/scalars
                delta = delta + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat = {k: upd(grads[k], state["m"][k], state["v"][k], params[k]) for k in params}
        new_params = {k: v[0] for k, v in flat.items()}
        new_state = {
            "m": {k: v[1] for k, v in flat.items()},
            "v": {k: v[2] for k, v in flat.items()},
            "step": step,
        }
        return new_params, new_state


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), no momentum
# ---------------------------------------------------------------------------

class Adafactor:
    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg
        self.sched = warmup_cosine(cfg)

    def _factored(self, shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= self.cfg.factored_min_dim \
            and shape[-2] >= self.cfg.factored_min_dim

    def init(self, params):
        state = {"step": jnp.zeros((), jnp.int32), "vr": {}, "vc": {}, "v": {}}
        for k, p in params.items():
            if self._factored(p.shape):
                state["vr"][k] = jnp.zeros(p.shape[:-1], jnp.float32)
                state["vc"][k] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            else:
                state["v"][k] = jnp.zeros(p.shape, jnp.float32)
        return state

    def state_templates(self, templates: Dict[str, ParamSpec]) -> Dict[str, Dict]:
        out = {"step": ParamSpec((), "int32", ()), "vr": {}, "vc": {}, "v": {}}
        for k, t in templates.items():
            if self._factored(t.shape):
                out["vr"][k] = ParamSpec(t.shape[:-1], "float32", t.axes[:-1], stacked=t.stacked)
                out["vc"][k] = ParamSpec(t.shape[:-2] + t.shape[-1:], "float32",
                                         t.axes[:-2] + t.axes[-1:], stacked=t.stacked)
            else:
                out["v"][k] = ParamSpec(t.shape, "float32", t.axes, stacked=t.stacked)
        return out

    def update(self, grads, state, params):
        c = self.cfg
        step = state["step"] + 1
        lr = self.sched(step)
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-c.decay_rate)

        new_params, vr_s, vc_s, v_s = {}, {}, {}, {}
        for k, p in params.items():
            g = grads[k].astype(jnp.float32)
            g2 = jnp.square(g) + 1e-30
            if self._factored(p.shape):
                vr = beta2 * state["vr"][k] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * state["vc"][k] + (1 - beta2) * jnp.mean(g2, axis=-2)
                vr_s[k], vc_s[k] = vr, vc
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = g * jax.lax.rsqrt(jnp.maximum(vr[..., None] / denom[..., None], 1e-30)) \
                      * jax.lax.rsqrt(jnp.maximum(vc[..., None, :], 1e-30))
            else:
                v = beta2 * state["v"][k] + (1 - beta2) * g2
                v_s[k] = v
                u = g * jax.lax.rsqrt(jnp.maximum(v, 1e-30))
            # update clipping (RMS(u) <= 1)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u)
            delta = u
            if p.ndim >= 2:
                delta = delta + c.weight_decay * p.astype(jnp.float32)
            new_params[k] = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_params, {"step": step, "vr": vr_s, "vc": vc_s, "v": v_s}


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return AdamW(cfg)
    if cfg.name == "adafactor":
        return Adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
