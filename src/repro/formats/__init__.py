"""Model serialization formats (dependency-free safetensors, model cards)."""
