"""Model-card / config metadata parsing for model-tree construction
(paper §4.4.3 step 3a).

The paper combines regular expressions with an LLM-based parser over
README.md / config.json to extract base-model lineage. This container has no
LLM endpoint, so the regex battery carries the full load (the LLM fallback is
stubbed — noted in DESIGN.md); the bit-distance matcher (step 3b) covers
whatever metadata misses, exactly as the paper designs it.
"""

from __future__ import annotations

import json
import os
import re
from typing import Dict, Optional

__all__ = ["parse_base_model", "parse_repo_metadata"]

# YAML frontmatter / markdown patterns seen on the Hub
_PATTERNS = [
    re.compile(r"^base_model:\s*[\"']?([\w\-./]+)[\"']?\s*$", re.M),
    re.compile(r"^base_model_relation:.*$\n^base_model:\s*[\"']?([\w\-./]+)", re.M),
    re.compile(r"(?:fine[- ]?tuned?|adapter)\s+(?:of|from|for)\s+\[?([\w\-./]+)\]?", re.I),
    re.compile(r"This model is a fine-tuned version of \[([\w\-./]+)\]", re.I),
]


def parse_base_model(readme_text: str = "", config: Optional[Dict] = None) -> Optional[str]:
    """Extract the declared base model id, or None if metadata is missing."""
    for pat in _PATTERNS:
        m = pat.search(readme_text or "")
        if m:
            return m.group(1).strip()
    if config:
        for key in ("base_model", "_name_or_path", "parent_model"):
            v = config.get(key)
            if isinstance(v, str) and v and v not in (".", "/"):
                return v
    return None


def parse_repo_metadata(repo_dir: str) -> Dict[str, Optional[str]]:
    """Read config.json / README.md from a repo directory."""
    out: Dict[str, Optional[str]] = {"base_model": None, "architecture": None}
    cfg_path = os.path.join(repo_dir, "config.json")
    readme_path = os.path.join(repo_dir, "README.md")
    config = None
    if os.path.exists(cfg_path):
        try:
            with open(cfg_path) as f:
                config = json.load(f)
            archs = config.get("architectures")
            if archs:
                out["architecture"] = archs[0]
        except (json.JSONDecodeError, OSError):
            config = None
    readme = ""
    if os.path.exists(readme_path):
        try:
            with open(readme_path, encoding="utf-8", errors="replace") as f:
                readme = f.read()
        except OSError:
            pass
    out["base_model"] = parse_base_model(readme, config)
    return out
