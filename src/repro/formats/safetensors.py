"""Dependency-free safetensors reader/writer.

Implements the safetensors on-disk format (https://github.com/huggingface/safetensors):

    [8 bytes LE u64: header_len][header_len bytes: JSON header][tensor data]

Header JSON maps tensor name -> {"dtype": str, "shape": [...], "data_offsets": [b, e]}
plus an optional "__metadata__" string->string map. Offsets are relative to the
end of the header. Tensors are serialized little-endian, row-major, unaligned.

The paper's pipeline (§4.1) depends on exactly this structure: the header gives
tensor boundaries for TensorDedup and float alignment for BitX, with zero-copy
per-tensor access. We implement it from scratch (no `safetensors` dependency in
this container) with two additions the paper calls for in §6:

* ``tensor_order`` — we always write tensors in *insertion order* and record it,
  so BitX alignment never degrades from alphabetical reordering.
* memory-mapped reads — per-tensor ``np.memmap`` views so TensorDedup can hash
  tensors in parallel without loading the full file.
"""

from __future__ import annotations

import json
import mmap
import os
import struct
from dataclasses import dataclass
from typing import Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "DTYPE_TO_STR",
    "STR_TO_DTYPE",
    "TensorInfo",
    "SafetensorsFile",
    "save_file",
    "load_file",
    "read_header",
    "read_header_blob",
    "iter_tensors",
]

# safetensors dtype tags. bfloat16 has no numpy dtype; we represent it as a
# uint16 view tagged "BF16" (bit-identical, which is all the storage layer needs).
DTYPE_TO_STR: Dict[str, str] = {
    "float64": "F64",
    "float32": "F32",
    "float16": "F16",
    "bfloat16": "BF16",
    "int64": "I64",
    "int32": "I32",
    "int16": "I16",
    "int8": "I8",
    "uint8": "U8",
    "uint16": "U16",
    "uint32": "U32",
    "uint64": "U64",
    "bool": "BOOL",
}

STR_TO_DTYPE: Dict[str, np.dtype] = {
    "F64": np.dtype("<f8"),
    "F32": np.dtype("<f4"),
    "F16": np.dtype("<f2"),
    "BF16": np.dtype("<u2"),  # bit view; semantic dtype kept in TensorInfo.dtype_str
    "I64": np.dtype("<i8"),
    "I32": np.dtype("<i4"),
    "I16": np.dtype("<i2"),
    "I8": np.dtype("i1"),
    "U8": np.dtype("u1"),
    "U16": np.dtype("<u2"),
    "U32": np.dtype("<u4"),
    "U64": np.dtype("<u8"),
    "BOOL": np.dtype("?"),
}

ITEMSIZE: Dict[str, int] = {k: v.itemsize for k, v in STR_TO_DTYPE.items()}

_HEADER_LEN_FMT = "<Q"


@dataclass(frozen=True)
class TensorInfo:
    """Metadata for one tensor inside a safetensors file."""

    name: str
    dtype_str: str  # safetensors tag, e.g. "BF16"
    shape: Tuple[int, ...]
    data_offsets: Tuple[int, int]  # relative to end of header

    @property
    def nbytes(self) -> int:
        return self.data_offsets[1] - self.data_offsets[0]

    @property
    def numel(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def np_view_dtype(self) -> np.dtype:
        return STR_TO_DTYPE[self.dtype_str]


def _normalize_array(arr: np.ndarray) -> Tuple[str, np.ndarray]:
    """Return (safetensors dtype tag, contiguous LE byte-compatible array)."""
    # ml_dtypes bfloat16 support: detect by name so we do not import ml_dtypes here.
    name = arr.dtype.name
    if name == "bfloat16":
        return "BF16", np.ascontiguousarray(arr).view(np.uint16)
    if name not in DTYPE_TO_STR:
        raise ValueError(f"unsupported dtype for safetensors: {arr.dtype}")
    tag = DTYPE_TO_STR[name]
    out = np.ascontiguousarray(arr)
    if out.dtype.byteorder == ">":
        out = out.astype(out.dtype.newbyteorder("<"))
    return tag, out


def save_file(
    tensors: Mapping[str, np.ndarray],
    path: str | os.PathLike,
    metadata: Optional[Mapping[str, str]] = None,
    dtype_tags: Optional[Mapping[str, str]] = None,
) -> None:
    """Write ``tensors`` to ``path`` in safetensors format.

    ``dtype_tags`` optionally overrides the dtype tag per tensor — used to write
    a uint16 bit-view as "BF16" (the storage layer moves raw bits around).
    Tensors are written in *insertion order* and that order is recorded in
    ``__metadata__["tensor_order"]`` (§6 of the paper: order-preserving headers).
    """
    header: Dict[str, object] = {}
    payloads: List[np.ndarray] = []
    offset = 0
    order: List[str] = []
    for name, arr in tensors.items():
        if dtype_tags and name in dtype_tags:
            tag = dtype_tags[name]
            buf = np.ascontiguousarray(arr).view(STR_TO_DTYPE[tag])
        else:
            tag, buf = _normalize_array(np.asarray(arr))
        nbytes = buf.nbytes
        header[name] = {
            "dtype": tag,
            "shape": list(np.asarray(arr).shape),
            "data_offsets": [offset, offset + nbytes],
        }
        payloads.append(buf)
        order.append(name)
        offset += nbytes

    meta: Dict[str, str] = dict(metadata or {})
    meta.setdefault("tensor_order", json.dumps(order))
    header["__metadata__"] = meta

    hjson = json.dumps(header, separators=(",", ":")).encode("utf-8")
    # safetensors pads the header with spaces to 8-byte alignment.
    pad = (8 - len(hjson) % 8) % 8
    hjson += b" " * pad

    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(struct.pack(_HEADER_LEN_FMT, len(hjson)))
        f.write(hjson)
        for buf in payloads:
            f.write(buf.tobytes())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic commit


def read_header(path: str | os.PathLike) -> Tuple[List[TensorInfo], Dict[str, str], int]:
    """Parse just the header. Returns (infos in serialization order, metadata,
    absolute byte offset where tensor data begins)."""
    with open(path, "rb") as f:
        (hlen,) = struct.unpack(_HEADER_LEN_FMT, f.read(8))
        hjson = f.read(hlen)
    return _parse_header(hjson, hlen)


def read_header_blob(blob: bytes) -> Tuple[List[TensorInfo], Dict[str, str], int]:
    """:func:`read_header` over in-memory file bytes (``[8-byte len][JSON
    header]...``) — e.g. the header blob a near-dup index entry stores."""
    (hlen,) = struct.unpack(_HEADER_LEN_FMT, blob[:8])
    return _parse_header(bytes(blob[8:8 + hlen]), hlen)


def _parse_header(hjson: bytes, hlen: int) -> Tuple[List[TensorInfo], Dict[str, str], int]:
    header = json.loads(hjson)
    metadata = {str(k): str(v) for k, v in (header.pop("__metadata__", {}) or {}).items()}
    infos = [
        TensorInfo(
            name=name,
            dtype_str=spec["dtype"],
            shape=tuple(int(s) for s in spec["shape"]),
            data_offsets=(int(spec["data_offsets"][0]), int(spec["data_offsets"][1])),
        )
        for name, spec in header.items()
    ]
    # Serialization order == offset order (the property BitX alignment needs).
    infos.sort(key=lambda ti: ti.data_offsets[0])
    return infos, metadata, 8 + hlen


class SafetensorsFile:
    """Zero-copy reader: per-tensor memory-mapped views.

    The paper's TensorDedup (§4.4.2) hashes tensors independently and in
    parallel; mmap views let workers touch only their tensor's pages.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        self.infos, self.metadata, self.data_start = read_header(self.path)
        self._by_name = {ti.name: ti for ti in self.infos}
        self._file = open(self.path, "rb")
        self._mmap = mmap.mmap(self._file.fileno(), 0, access=mmap.ACCESS_READ)

    # -- context manager -------------------------------------------------
    def __enter__(self) -> "SafetensorsFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        try:
            self._mmap.close()
        except BufferError:
            # zero-copy views handed out (np.frombuffer) are still alive; the
            # mmap closes when they are collected. Intentional: TensorDedup /
            # BitX hold tensor views only transiently.
            pass
        finally:
            self._file.close()

    # -- access -----------------------------------------------------------
    _ADVICE = {"sequential": "MADV_SEQUENTIAL", "random": "MADV_RANDOM",
               "willneed": "MADV_WILLNEED"}

    def advise(self, mode: str = "sequential") -> None:
        """Hint the kernel about the upcoming access pattern (madvise).

        The ingest engine walks tensors in serialization order
        ("sequential"); parallel workers resolving base tensors jump around
        ("random"). No-op on platforms without mmap.madvise.
        """
        flag = getattr(mmap, self._ADVICE[mode], None)
        if flag is not None and hasattr(self._mmap, "madvise"):
            self._mmap.madvise(flag)

    def names(self) -> List[str]:
        return [ti.name for ti in self.infos]

    def info(self, name: str) -> TensorInfo:
        return self._by_name[name]

    def tensor_bytes(self, name: str) -> memoryview:
        ti = self._by_name[name]
        b, e = ti.data_offsets
        return memoryview(self._mmap)[self.data_start + b : self.data_start + e]

    def tensor(self, name: str) -> np.ndarray:
        """Bit-view array (BF16 tensors come back as uint16 views)."""
        ti = self._by_name[name]
        arr = np.frombuffer(self.tensor_bytes(name), dtype=ti.np_view_dtype)
        return arr.reshape(ti.shape)

    def __iter__(self) -> Iterator[Tuple[TensorInfo, np.ndarray]]:
        for ti in self.infos:
            yield ti, self.tensor(ti.name)


def load_file(path: str | os.PathLike) -> Dict[str, np.ndarray]:
    """Load every tensor into memory (bit views for BF16). Copies out of mmap."""
    with SafetensorsFile(path) as sf:
        return {ti.name: np.array(sf.tensor(ti.name)) for ti in sf.infos}


def iter_tensors(path: str | os.PathLike) -> Iterator[Tuple[TensorInfo, np.ndarray]]:
    with SafetensorsFile(path) as sf:
        for ti, arr in sf:
            yield ti, arr
