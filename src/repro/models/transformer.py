"""Decoder-only transformer LM family.

Covers seven of the ten assigned architectures through one config-driven
implementation: qwen2-7b / qwen2-vl-7b (GQA, QKV bias, M-RoPE), granite-20b
(MQA), phi4-mini (partial rotary), deepseek-coder-33b, mixtral-8x7b (MoE +
SWA), grok-1-314b (MoE).

Structure: pre-norm blocks, scan-over-layers with per-layer remat (the scan
keeps the HLO a single stacked layer — essential for 62-layer × 512-device
lowering), GQA attention expanded to H heads for TP, SwiGLU or top-k MoE MLPs,
chunked cross-entropy against a TP-sharded lm_head.

Three entry points mirror the assigned shape kinds:

* ``loss(params, batch)``          — train_4k (grad/optimizer wrapping lives in
                                     ``repro.train.step``)
* ``prefill(params, batch)``       — prefill_32k: full forward, returns last-
                                     position logits + a sequence-sharded cache
* ``decode_step(params, batch)``   — decode_32k / long_500k: one token against
                                     the cache (flash-decoding via shard_map)
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.sharding.rules import ParamSpec, ShardingRules, named_sharding, safe_entry

__all__ = ["TransformerLM"]


class TransformerLM:
    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None, remat_policy: str = "nothing"):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.remat_policy = remat_policy

    # ------------------------------------------------------------------
    # Parameter templates
    # ------------------------------------------------------------------
    def param_templates(self) -> Dict[str, ParamSpec]:
        c = self.cfg
        hd, H, Kv, d, f, V, Ln = c.hd, c.n_heads, c.n_kv_heads, c.d_model, c.d_ff, c.vocab, c.n_layers
        dt = c.param_dtype
        out_scale = 0.02 / (2 * Ln) ** 0.5
        t: Dict[str, ParamSpec] = {
            "embed": ParamSpec((V, d), dt, ("tp", None), init="normal"),
            "final_norm": ParamSpec((d,), dt, (None,), init="ones"),
        }
        if not c.tie_embeddings:
            t["lm_head"] = ParamSpec((d, V), dt, ("fsdp", "tp"), init="normal")
        blk = {
            "attn_norm": ParamSpec((Ln, d), dt, (None, None), init="ones", stacked=True),
            "wq": ParamSpec((Ln, d, H * hd), dt, (None, "fsdp", "tp"), stacked=True),
            "wk": ParamSpec((Ln, d, Kv * hd), dt, (None, "fsdp", "tp"), stacked=True),
            "wv": ParamSpec((Ln, d, Kv * hd), dt, (None, "fsdp", "tp"), stacked=True),
            "wo": ParamSpec((Ln, H * hd, d), dt, (None, "tp", "fsdp"),
                            init="scaled", init_scale=out_scale, stacked=True),
            "mlp_norm": ParamSpec((Ln, d), dt, (None, None), init="ones", stacked=True),
        }
        if c.qkv_bias:
            blk["bq"] = ParamSpec((Ln, H * hd), dt, (None, "tp"), init="zeros", stacked=True)
            blk["bk"] = ParamSpec((Ln, Kv * hd), dt, (None, "tp"), init="zeros", stacked=True)
            blk["bv"] = ParamSpec((Ln, Kv * hd), dt, (None, "tp"), init="zeros", stacked=True)
        if c.moe is not None:
            E = c.moe.n_experts
            blk["router"] = ParamSpec((Ln, d, E), dt, (None, "fsdp", None), stacked=True)
            blk["moe_gate"] = ParamSpec((Ln, E, d, f), dt, (None, "expert", "fsdp", "tp"), stacked=True)
            blk["moe_up"] = ParamSpec((Ln, E, d, f), dt, (None, "expert", "fsdp", "tp"), stacked=True)
            blk["moe_down"] = ParamSpec((Ln, E, f, d), dt, (None, "expert", "tp", "fsdp"),
                                        init="scaled", init_scale=out_scale, stacked=True)
        else:
            blk["w_gate"] = ParamSpec((Ln, d, f), dt, (None, "fsdp", "tp"), stacked=True)
            blk["w_up"] = ParamSpec((Ln, d, f), dt, (None, "fsdp", "tp"), stacked=True)
            blk["w_down"] = ParamSpec((Ln, f, d), dt, (None, "tp", "fsdp"),
                                      init="scaled", init_scale=out_scale, stacked=True)
        t.update({f"blocks.{k}": v for k, v in blk.items()})
        return t

    def param_count(self) -> int:
        n = 0
        for spec in self.param_templates().values():
            c = 1
            for s in spec.shape:
                c *= s
            n += c
        return n

    def active_param_count(self) -> int:
        c = self.cfg
        if c.moe is None:
            return self.param_count()
        n = 0
        E, k = c.moe.n_experts, c.moe.top_k
        for name, spec in self.param_templates().items():
            cnt = 1
            for s in spec.shape:
                cnt *= s
            if "moe_" in name:
                cnt = cnt * k // E
            n += cnt
        return n

    # ------------------------------------------------------------------
    # Sharding helpers
    # ------------------------------------------------------------------
    def _ws(self, x: jax.Array, *axes) -> jax.Array:
        if self.mesh is None or self.rules is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, named_sharding(self.mesh, axes, self.rules, x.shape))

    def _dp_degree(self) -> int:
        if self.mesh is None or self.rules is None:
            return 1
        n = 1
        for a in self.rules.batch:
            n *= self.mesh.shape.get(a, 1)
        return n

    def _remat(self, fn):
        policies = {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            "none": None,
        }
        pol = policies[self.remat_policy]
        if self.remat_policy == "none":
            return fn
        return jax.checkpoint(fn, policy=pol)

    # ------------------------------------------------------------------
    # Blocks
    # ------------------------------------------------------------------
    def _qkv(self, x, p, positions, positions3=None):
        """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,Kv,hd) with RoPE applied."""
        c = self.cfg
        B, S, _ = x.shape
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
        if c.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = q.reshape(B, S, c.n_heads, c.hd)
        k = k.reshape(B, S, c.n_kv_heads, c.hd)
        v = v.reshape(B, S, c.n_kv_heads, c.hd)
        if c.mrope:
            q = L.apply_mrope(q, positions3, c.rope_theta)
            k = L.apply_mrope(k, positions3, c.rope_theta)
        else:
            q = L.apply_rope(q, positions, c.rope_theta, c.rope_pct)
            k = L.apply_rope(k, positions, c.rope_theta, c.rope_pct)
        return q, k, v

    def _mlp(self, x, p):
        c = self.cfg
        if c.moe is not None:
            return L.moe_block(
                x, p["router"], p["moe_gate"], p["moe_up"], p["moe_down"],
                top_k=c.moe.top_k, capacity_factor=c.moe.capacity_factor,
                n_groups=self._dp_degree(), ws=self._ws)
        return L.swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0.0)

    def _block_full(self, h, p, positions, positions3, causal=True):
        """One transformer block over a full sequence. Returns (h, (k, v), aux)."""
        c = self.cfg
        x = L.rms_norm(h, p["attn_norm"])
        q, k, v = self._qkv(x, p, positions, positions3)
        q = self._ws(q, "batch", None, "tp", None)
        kH = L.repeat_kv(k, c.n_heads)
        vH = L.repeat_kv(v, c.n_heads)
        kH = self._ws(kH, "batch", None, "tp", None)
        vH = self._ws(vH, "batch", None, "tp", None)
        attn = L.attention(q, kH, vH, causal=causal, window=c.swa_window,
                           score_dtype=jnp.dtype(c.attn_score_dtype),
                           chunk_q=c.attn_chunk_q, chunk_kv=c.attn_chunk_kv)
        B, S = h.shape[:2]
        h = h + jnp.einsum("bsh,hd->bsd", attn.reshape(B, S, -1), p["wo"])
        x = L.rms_norm(h, p["mlp_norm"])
        mlp_out, aux = self._mlp(x, p)
        h = h + mlp_out
        h = self._ws(h, "batch", None, None)
        return h, (k, v), aux

    def _lm_head(self, params):
        """(d, V) output projection; the transpose of embed when tied (phi-4)."""
        return params["lm_head"] if "lm_head" in params else params["embed"].T

    # ------------------------------------------------------------------
    # Embedding
    # ------------------------------------------------------------------
    def _embed(self, params, batch):
        """Token embedding (+ additive patch-embedding stub for the VLM)."""
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        if self.cfg.mrope and "patch_embeds" in batch:
            h = h + batch["patch_embeds"].astype(h.dtype)
        return self._ws(h, "batch", None, None)

    def _positions(self, batch, B, S, offset=0):
        c = self.cfg
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.arange(S)[None, :] + offset
            pos = jnp.broadcast_to(pos, (B, S))
        if c.mrope:
            p3 = batch.get("positions3")
            if p3 is None:
                p3 = jnp.broadcast_to(pos[None], (3, B, S))
            elif p3.ndim == 3 and p3.shape[1] == 3:
                p3 = p3.transpose(1, 0, 2)   # (B, 3, S) input layout -> (3, B, S)
            return pos, p3
        return pos, None

    # ------------------------------------------------------------------
    # Entry points
    # ------------------------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        """Mean next-token CE. batch: tokens (B,S) int32, labels (B,S) int32
        (+ patch_embeds / positions3 for the VLM)."""
        c = self.cfg
        B, S = batch["tokens"].shape
        h = self._embed(params, batch)
        positions, positions3 = self._positions(batch, B, S)
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("blocks.")}

        def layer(carry, p):
            h, aux = carry
            h, _, a = self._block_full(h, p, positions, positions3)
            return (h, aux + a), None

        (h, aux), _ = jax.lax.scan(self._remat(layer), (h, jnp.float32(0.0)), stacked)
        h = L.rms_norm(h, params["final_norm"])
        ce = L.chunked_cross_entropy(h, self._lm_head(params), batch["labels"])
        if c.moe is not None:
            ce = ce + 0.01 * aux / c.n_layers
        return ce

    def prefill(self, params, batch):
        """Full forward pass; returns (last-position logits (B, V), cache)."""
        c = self.cfg
        B, S = batch["tokens"].shape
        h = self._embed(params, batch)
        positions, positions3 = self._positions(batch, B, S)
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("blocks.")}

        def layer(h, p):
            h, (k, v), _ = self._block_full(h, p, positions, positions3)
            k = self._ws(k, "batch", "sp", None, None)
            v = self._ws(v, "batch", "sp", None, None)
            return h, (k, v)

        h, (ks, vs) = jax.lax.scan(self._remat(layer), h, stacked)
        h = L.rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._lm_head(params),
                            preferred_element_type=jnp.float32)
        cache = {
            "k": self._ws(ks, None, "batch", "sp", None, None),
            "v": self._ws(vs, None, "batch", "sp", None, None),
            "len": jnp.int32(S),
        }
        return logits, cache

    def decode_step(self, params, batch, cache):
        """One-token decode. batch: tokens (B, 1). cache: k/v (L, B, Smax, Kv, hd)
        sequence-sharded + ``len``. Returns (logits (B, V), new cache)."""
        c = self.cfg
        B = batch["tokens"].shape[0]
        t = cache["len"]
        h = self._embed(params, batch)                     # (B, 1, d)
        positions = jnp.full((B, 1), t, jnp.int32)
        positions3 = jnp.broadcast_to(positions[None], (3, B, 1)) if c.mrope else None
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("blocks.")}

        Smax = cache["k"].shape[2]
        rolling = bool(c.swa_window) and Smax <= c.swa_window
        # rolling SWA cache: writes wrap modulo the window; every resident
        # entry is in-window by construction, so no window mask is needed
        wpos = (t % Smax) if rolling else t
        valid_len = jnp.minimum(t + 1, Smax)

        def layer(h, xs):
            p, k_cache, v_cache = xs
            x = L.rms_norm(h, p["attn_norm"])
            q, k, v = self._qkv(x, p, positions, positions3)
            # write new kv at position wpos (GSPMD turns this into a masked
            # owner-shard update on the sequence-sharded cache)
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), wpos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), wpos, axis=1)
            if self.mesh is not None and "model" in self.mesh.shape and self.mesh.shape["model"] > 1:
                attn = L.decode_attention_sp(
                    q[:, 0], k_cache, v_cache, valid_len,
                    mesh=self.mesh, sp_axis="model",
                    batch_axes=(safe_entry(self.mesh, self.rules, "batch", q.shape[0]),),
                    window=0 if rolling else c.swa_window)
            else:
                kH = L.repeat_kv(k_cache, c.n_heads)
                vH = L.repeat_kv(v_cache, c.n_heads)
                # query acts at index valid_len-1: cache entries < valid_len
                # are visible, garbage beyond is masked (order within a rolled
                # window is irrelevant to softmax)
                attn = L.attention(q, kH, vH, causal=True, q_offset=valid_len - 1,
                                   window=0 if rolling else c.swa_window)[:, 0]
            h = h + jnp.einsum("bh,hd->bd", attn.reshape(B, -1), p["wo"])[:, None]
            x = L.rms_norm(h, p["mlp_norm"])
            mlp_out, _ = self._mlp(x, p)
            return h + mlp_out, (k_cache, v_cache)

        h, (ks, vs) = jax.lax.scan(layer, h, (stacked, cache["k"], cache["v"]))
        h = L.rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._lm_head(params),
                            preferred_element_type=jnp.float32)
        return logits, {"k": ks, "v": vs, "len": t + 1}

    # ------------------------------------------------------------------
    # Cache specs (dry-run stand-ins)
    # ------------------------------------------------------------------
    def cache_templates(self, batch: int, seq: int) -> Dict[str, ParamSpec]:
        c = self.cfg
        # rolling SWA cache for long-context decode
        S = min(seq, c.swa_window) if (c.swa_window and seq > c.swa_window) else seq
        kv = (c.n_layers, batch, S, c.n_kv_heads, c.hd)
        axes = (None, "batch", "sp", None, None)
        return {
            "k": ParamSpec(kv, c.act_dtype, axes),
            "v": ParamSpec(kv, c.act_dtype, axes),
            "len": ParamSpec((), "int32", ()),
        }
