"""Shared neural building blocks for every assigned architecture.

Pure functions over flat parameter dicts (no framework classes): RMS/layer
norm, RoPE + M-RoPE, attention in three flavours (full-masked for short
sequences, chunked flash-style for long prefill, shard_map flash-decoding over
a sequence-sharded KV cache for decode), SwiGLU / GELU MLPs, scatter-based
top-k MoE dispatch, and a chunked cross-entropy that never materializes the
full (B, S, V) logits tensor.

Everything lowers through pjit/GSPMD: we only annotate inputs/params and a few
strategic ``with_sharding_constraint`` points and let propagation do the rest.
The one exception is decode attention, which uses ``shard_map`` because online
softmax over a sequence-sharded cache is a reduction GSPMD cannot derive.

TP note on GQA: attention runs with KV expanded to the full H query heads
(``repeat_kv``) so every attention tensor carries one H dim that shards over
the "model" axis — Megatron-style KV-head duplication. The expansion is a
transient compute-side view; decode caches stay at Kv heads and expand locally
inside the shard_map body.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "rms_norm",
    "layer_norm",
    "rope_table",
    "apply_rope",
    "apply_mrope",
    "repeat_kv",
    "attention",
    "decode_attention_sp",
    "swiglu",
    "gelu_mlp",
    "moe_block",
    "chunked_cross_entropy",
]

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_table(positions: jax.Array, rot_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for integer positions: (..., S) -> (..., S, rot_dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate (x1, x2) half-pairs of the rotary slice. x: (..., rot_dim)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, rope_pct: float = 1.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S). Partial rotary via rope_pct (phi-4)."""
    d = x.shape[-1]
    rot = int(d * rope_pct)
    rot -= rot % 2
    cos, sin = rope_table(positions, rot, theta)  # (B, S, rot/2)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    xr = _rotate(x[..., :rot], cos, sin)
    if rot < d:
        xr = jnp.concatenate([xr, x[..., rot:].astype(jnp.float32)], axis=-1)
    return xr.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float,
    sections: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the three position streams (t, h, w) drive
    disjoint frequency sections of the rotary dim.

    x: (B, S, H, D); positions3: (3, B, S); sum(sections) == D // 2. The
    default split is the published (16, 24, 24) t/h/w ratio scaled to D
    (exactly (16, 24, 24) at D=128).
    """
    d = x.shape[-1]
    half = d // 2
    if sections is None:
        t_sec = half // 4
        h_sec = (half - t_sec) // 2
        sections = (t_sec, h_sec, half - t_sec - h_sec)
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))       # (half,)
    ang = positions3.astype(jnp.float32)[..., None] * freqs                     # (3, B, S, half)
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    pick = jax.nn.one_hot(sel, 3, dtype=jnp.float32)                            # (half, 3)
    ang = jnp.einsum("tbsf,ft->bsf", ang, pick)                                 # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (train / prefill): chunked flash-style, GQA expanded to H heads
# ---------------------------------------------------------------------------

def repeat_kv(kv: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Kv, D) -> (B, S, H, D) by repeating each kv head H/Kv times."""
    B, S, Kv, D = kv.shape
    if Kv == n_heads:
        return kv
    reps = n_heads // Kv
    return jnp.repeat(kv, reps, axis=2)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int = 0,
    window: int = 0,
    chunk_q: int = 1024,
    chunk_kv: int = 1024,
    score_dtype=jnp.float32,
) -> jax.Array:
    """Memory-bounded attention over H-head q/k/v.

    q: (B, Sq, H, D); k, v: (B, Sk, H, D) (GQA already expanded by the caller).
    ``causal`` masks j > i + q_offset; ``window > 0`` additionally masks
    j <= i + q_offset - window (sliding-window attention, Mixtral).

    Short sequences take the single-block masked path; long sequences scan over
    q chunks (outer) and kv chunks (inner) with an online-softmax accumulator
    (flash semantics): peak score memory is O(B·H·chunk_q·chunk_kv), never
    O(Sq·Sk).
    """
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    assert Hk == H, (Hk, H)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    if Sq <= 2048 and Sk <= 2048:
        s = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32) * scale
        if causal or window:
            qi = jnp.arange(Sq)[:, None] + q_offset
            kj = jnp.arange(Sk)[None, :]
            mask = jnp.ones((Sq, Sk), bool)
            if causal:
                mask &= kj <= qi
            if window:
                mask &= kj > qi - window
            s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)
        return out

    chunk_q = min(chunk_q, Sq)
    chunk_kv = min(chunk_kv, Sk)
    assert Sq % chunk_q == 0 and Sk % chunk_kv == 0, (Sq, Sk, chunk_q, chunk_kv)
    nq, nk = Sq // chunk_q, Sk // chunk_kv
    qc = q.reshape(B, nq, chunk_q, H, D).swapaxes(0, 1)   # (nq, B, cq, H, D)
    kc = k.reshape(B, nk, chunk_kv, H, D).swapaxes(0, 1)  # (nk, B, ck, H, D)
    vc = v.reshape(B, nk, chunk_kv, H, D).swapaxes(0, 1)

    def q_chunk_body(_, qi_block):
        qi, qblk = qi_block  # (B, cq, H, D)

        # flash backward semantics: WITHOUT this checkpoint, scan saves the
        # (B, H, cq, ckv) score/softmax residuals of EVERY (qi, kj) pair —
        # the full O(S²) matrix in fp32 — as stacked residuals for the
        # backward pass. Checkpointing the body keeps only the (m, l, o)
        # accumulators per step and recomputes scores in the backward.
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_body(acc, kv_block):
            m, l, o = acc
            kj, kblk, vblk = kv_block
            s = jnp.einsum("bqhd,bshd->bhqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if causal or window:
                qpos = qi * chunk_q + jnp.arange(chunk_q)[:, None] + q_offset
                kpos = kj * chunk_kv + jnp.arange(chunk_kv)[None, :]
                msk = jnp.ones((chunk_q, chunk_kv), bool)
                if causal:
                    msk &= kpos <= qpos
                if window:
                    msk &= kpos > qpos - window
                s = jnp.where(msk, s, NEG_INF)
            if score_dtype != jnp.float32:
                # store the O(cq·ckv) block compressed between fusions; the
                # dot accumulates f32, max/exp upcast locally (bf16 max error
                # ~0.4% of softmax mass — the §Perf memory-term lever)
                s = s.astype(score_dtype).astype(jnp.float32)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, H, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, chunk_q), jnp.float32)
        o0 = jnp.zeros((B, H, chunk_q, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_body, (m0, l0, o0), (jnp.arange(nk), kc, vc))
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)  # (B, H, cq, D)
        return None, out.transpose(0, 2, 1, 3)                         # (B, cq, H, D)

    q_chunk_body = jax.checkpoint(q_chunk_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(q_chunk_body, None, (jnp.arange(nq), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# Decode attention: flash-decoding over a sequence-sharded KV cache
# ---------------------------------------------------------------------------

def decode_attention_sp(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    mesh: Mesh,
    sp_axis: str = "model",
    batch_axes=None,
    window: int = 0,
) -> jax.Array:
    """One-token attention over a cache whose sequence dim is sharded.

    q: (B, H, D) replicated over ``sp_axis``; k_cache/v_cache: (B, S, Kv, D)
    sharded P(batch_axes, sp_axis, None, None); cache_len: scalar int32 —
    number of valid cache entries (positions >= cache_len are masked; with
    ``window`` > 0 positions <= cache_len - window are also masked).

    This is flash-decoding mapped onto the TPU mesh: each model-axis shard
    computes a partial online softmax over its local sequence chunk, then the
    partials merge with one pmax + two psums of (B, H·D)-sized tensors — bytes
    moved are O(B·H·D), not the O(B·S·Kv·D) cache all-gather GSPMD propagation
    would produce.
    """
    B, H, D = q.shape
    _, S, Kv, _ = k_cache.shape
    G = H // Kv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    baxes = tuple(batch_axes) if batch_axes is not None else (None,)

    def local(q, kc, vc, cache_len):
        # all shapes here are LOCAL shard shapes
        B, chunk = kc.shape[0], kc.shape[1]
        idx = jax.lax.axis_index(sp_axis)
        pos = idx * chunk + jnp.arange(chunk)          # global positions of my chunk
        qg = q.reshape(B, Kv, G, D)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        valid = pos < cache_len
        if window:
            valid &= pos > cache_len - window
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)                        # (B, Kv, G)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, sp_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, sp_axis)
        o_g = jax.lax.psum(o * corr[..., None], sp_axis)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(B, H, D).astype(q.dtype)

    cache_spec = P(*(baxes + (sp_axis, None, None)))
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(*(baxes + (None, None))), cache_spec, cache_spec, P()),
        out_specs=P(*(baxes + (None, None))),
        check_rep=False,
    )(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jnp.einsum("bsd,df->bsf", x, w_in)
    if b_in is not None:
        h = h + b_in
    h = jax.nn.gelu(h)
    o = jnp.einsum("bsf,fd->bsd", h, w_out)
    if b_out is not None:
        o = o + b_out
    return o


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity + scatter dispatch (GShard semantics)
# ---------------------------------------------------------------------------

def moe_block(
    x: jax.Array,
    router_w: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    top_k: int = 2,
    capacity_factor: float = 1.25,
    n_groups: int = 1,
    ws=None,
) -> Tuple[jax.Array, jax.Array]:
    """Token-dropping top-k MoE with GROUP-LOCAL scatter dispatch.

    x: (B, S, d); router_w: (d, E); expert weights: (E, d, f) / (E, f, d).
    Returns (output (B, S, d), aux load-balancing loss scalar).

    Tokens flatten into (G, T/G) groups — one group per data-parallel shard
    (``n_groups`` = DP degree) — and each group dispatches into ITS OWN
    (E, C_g, d) buffer, C_g = ceil((T/G)·k·cf/E), via a per-group cumsum +
    scatter-add. The group dim is batch-sharded, so dispatch, expert FFN and
    combine stay local in the data direction; expert weights are layer-wise
    all-gathered over the FSDP axis (ZeRO-3), never psum'd.

    The grouping is load-bearing: with one GLOBAL buffer the capacity dim
    cannot shard (slot ids come from a global cumsum), and GSPMD's only
    legal strategy keeps every token's expert activation on every shard and
    all-reduces f32 (E, C_global, f) partials each layer — observed as
    ~6 TB/device of collective traffic on grok-1 before this restructure.
    """
    B, S, d = x.shape
    E = router_w.shape[-1]
    T = B * S
    G = n_groups if n_groups > 0 and T % n_groups == 0 else 1
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    if ws is not None:
        xt = ws(xt, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (G, Tg, E)
    gate_w, expert_idx = jax.lax.top_k(probs, top_k)              # (G, Tg, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Switch-style aux loss: E * sum_e (fraction routed to e) * (mean prob e)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    cap = int(max(1, (Tg * top_k * capacity_factor) / E))

    flat_e = expert_idx.reshape(G, Tg * top_k)                    # (G, Tg·k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (G, Tg·k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot                # slots before me
    slot = jnp.sum(pos_in_e * onehot, axis=-1)                    # (G, Tg·k)
    keep = slot < cap

    xk = jnp.repeat(xt, top_k, axis=1)                            # (G, Tg·k, d)
    wk = gate_w.reshape(G, Tg * top_k)
    e_safe = jnp.where(keep, flat_e, 0)
    s_safe = jnp.where(keep, slot, 0)
    g_idx = jnp.broadcast_to(jnp.arange(G)[:, None], e_safe.shape)
    buf = jnp.zeros((G, E, cap, d), x.dtype)
    buf = buf.at[g_idx, e_safe, s_safe].add(jnp.where(keep[..., None], xk, 0))

    if ws is not None:
        # groups over DP; d_model FULL (the weights all-gather over FSDP
        # instead — the same ZeRO-3 pattern as the dense MLP); d_ff over TP
        buf = ws(buf, "batch", None, None, None)
    g = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    u = jnp.einsum("gecd,edf->gecf", buf, w_up)
    if ws is not None:
        g = ws(g, "batch", None, None, "tp")
        u = ws(u, "batch", None, None, "tp")
    yb = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, w_down)  # (G, E, C, d)
    if ws is not None:
        yb = ws(yb, "batch", None, None, None)

    gathered = yb[g_idx, e_safe, s_safe]                          # (G, Tg·k, d)
    gathered = jnp.where(keep[..., None], gathered, 0) * wk[..., None].astype(x.dtype)
    out = jnp.sum(gathered.reshape(G, Tg, top_k, d), axis=2)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Chunked cross-entropy: never materialize (B, S, V)
# ---------------------------------------------------------------------------

def chunked_cross_entropy(
    h: jax.Array,
    w_vocab: jax.Array,
    labels: jax.Array,
    *,
    chunk: int = 256,
) -> jax.Array:
    """Mean token CE of h @ w_vocab vs labels, computed in sequence chunks.

    h: (B, S, d); w_vocab: (d, V); labels: (B, S) int32 (< 0 = ignore).
    Each chunk's logits (B, chunk, V) are transient and rematerialized in the
    backward pass, so the full (B, S, V) tensor (tens of GB at 150k vocab)
    never exists. The gold logit is extracted with a one-hot einsum rather
    than take_along_axis so a vocab-sharded (TP) logits tensor reduces with a
    psum instead of an all-gather.
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    hc = h.reshape(B, n, chunk, d).swapaxes(0, 1)       # (n, B, c, d)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)     # (n, B, c)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_loss(hb, lb):
        logits = jnp.einsum("bcd,dv->bcv", hb, w_vocab, preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(lb.clip(0), logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("bcv,bcv->bc", logits, oh)
        valid = (lb >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    def body(carry, xs):
        tot, cnt = carry
        l, c = chunk_loss(*xs)
        return (tot + l, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
