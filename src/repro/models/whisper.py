"""Whisper-medium encoder-decoder backbone.

Per the assignment, the conv audio frontend is a STUB: ``input_specs`` (and
the smoke tests) provide precomputed frame embeddings (B, S_enc, d) in place
of the two conv1d layers over mel spectrograms. Everything downstream is real:
24 bidirectional encoder layers (MHA + GELU MLP, pre-LayerNorm), 24 decoder
layers (causal self-attention + cross-attention + GELU MLP), sinusoidal
positions, logits tied to the decoder token embedding.

Decode carries two caches: the growing decoder self-attention cache
(sequence-sharded, flash-decoding) and the fixed cross-attention K/V computed
once from the encoder output at prefill.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.sharding.rules import ParamSpec, ShardingRules, named_sharding, safe_entry

__all__ = ["WhisperModel", "sinusoid_positions"]


def sinusoid_positions(S: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None] + offset
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel:
    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None, remat_policy: str = "nothing"):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.remat_policy = remat_policy

    def param_templates(self) -> Dict[str, ParamSpec]:
        c = self.cfg
        d, f, V, Ln = c.d_model, c.d_ff, c.vocab, c.n_layers
        hd, H, Kv = c.hd, c.n_heads, c.n_kv_heads
        dt = c.param_dtype
        out_scale = 0.02 / (2 * 2 * Ln) ** 0.5
        t = {
            "embed": ParamSpec((V, d), dt, ("tp", None)),   # decoder tokens; tied logits
            "enc_final_norm": ParamSpec((d,), dt, (None,), init="ones"),
            "enc_final_bias": ParamSpec((d,), dt, (None,), init="zeros"),
            "dec_final_norm": ParamSpec((d,), dt, (None,), init="ones"),
            "dec_final_bias": ParamSpec((d,), dt, (None,), init="zeros"),
        }

        def attn_block(prefix, kv_heads):
            return {
                f"{prefix}_norm": ParamSpec((Ln, d), dt, (None, None), init="ones", stacked=True),
                f"{prefix}_norm_b": ParamSpec((Ln, d), dt, (None, None), init="zeros", stacked=True),
                f"{prefix}_wq": ParamSpec((Ln, d, H * hd), dt, (None, "fsdp", "tp"), stacked=True),
                f"{prefix}_wk": ParamSpec((Ln, d, kv_heads * hd), dt, (None, "fsdp", "tp"), stacked=True),
                f"{prefix}_wv": ParamSpec((Ln, d, kv_heads * hd), dt, (None, "fsdp", "tp"), stacked=True),
                f"{prefix}_wo": ParamSpec((Ln, H * hd, d), dt, (None, "tp", "fsdp"),
                                          init="scaled", init_scale=out_scale, stacked=True),
            }

        def mlp_block(prefix):
            return {
                f"{prefix}_norm": ParamSpec((Ln, d), dt, (None, None), init="ones", stacked=True),
                f"{prefix}_norm_b": ParamSpec((Ln, d), dt, (None, None), init="zeros", stacked=True),
                f"{prefix}_w_in": ParamSpec((Ln, d, f), dt, (None, "fsdp", "tp"), stacked=True),
                f"{prefix}_b_in": ParamSpec((Ln, f), dt, (None, "tp"), init="zeros", stacked=True),
                f"{prefix}_w_out": ParamSpec((Ln, f, d), dt, (None, "tp", "fsdp"),
                                             init="scaled", init_scale=out_scale, stacked=True),
                f"{prefix}_b_out": ParamSpec((Ln, d), dt, (None, None), init="zeros", stacked=True),
            }

        for grp in (attn_block("enc.attn", Kv), mlp_block("enc.mlp"),
                    attn_block("dec.self", Kv), attn_block("dec.cross", Kv),
                    mlp_block("dec.mlp")):
            t.update(grp)
        return t

    def param_count(self) -> int:
        n = 0
        for spec in self.param_templates().values():
            m = 1
            for s in spec.shape:
                m *= s
            n += m
        return n

    active_param_count = param_count

    def _ws(self, x, *axes):
        if self.mesh is None or self.rules is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, named_sharding(self.mesh, axes, self.rules, x.shape))

    def _remat(self, fn):
        if self.remat_policy == "none":
            return fn
        pol = {"nothing": jax.checkpoint_policies.nothing_saveable,
               "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable}[self.remat_policy]
        return jax.checkpoint(fn, policy=pol)

    # ------------------------------------------------------------------
    def _mha(self, x, kv_src, p, prefix, causal):
        c = self.cfg
        B, S, _ = x.shape
        Skv = kv_src.shape[1]
        q = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}_wq"]).reshape(B, S, c.n_heads, c.hd)
        k = jnp.einsum("bsd,dh->bsh", kv_src, p[f"{prefix}_wk"]).reshape(B, Skv, c.n_kv_heads, c.hd)
        v = jnp.einsum("bsd,dh->bsh", kv_src, p[f"{prefix}_wv"]).reshape(B, Skv, c.n_kv_heads, c.hd)
        kH, vH = L.repeat_kv(k, c.n_heads), L.repeat_kv(v, c.n_heads)
        attn = L.attention(q, kH, vH, causal=causal,
                           score_dtype=jnp.dtype(self.cfg.attn_score_dtype))
        out = jnp.einsum("bsh,hd->bsd", attn.reshape(B, S, -1), p[f"{prefix}_wo"])
        return out, (k, v)

    def _encoder(self, params, frames):
        """frames: (B, S_enc, d) precomputed conv-frontend embeddings."""
        B, S, d = frames.shape
        h = frames + sinusoid_positions(S, d).astype(frames.dtype)[None]
        h = self._ws(h, "batch", None, None)
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("enc.")}

        def layer(h, p):
            x = L.layer_norm(h, p["attn_norm"], p["attn_norm_b"])
            a, _ = self._mha(x, x, p, "attn", causal=False)
            h = h + a
            x = L.layer_norm(h, p["mlp_norm"], p["mlp_norm_b"])
            h = h + L.gelu_mlp(x, p["mlp_w_in"], p["mlp_b_in"], p["mlp_w_out"], p["mlp_b_out"])
            return h, None

        h, _ = jax.lax.scan(self._remat(layer), h, stacked)
        return L.layer_norm(h, params["enc_final_norm"], params["enc_final_bias"])

    def _decoder_full(self, params, tokens, enc_out):
        B, S = tokens.shape
        d = self.cfg.d_model
        h = jnp.take(params["embed"], tokens, axis=0)
        h = h + sinusoid_positions(S, d).astype(h.dtype)[None]
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("dec.")}

        def layer(h, p):
            x = L.layer_norm(h, p["self_norm"], p["self_norm_b"])
            a, (sk, sv) = self._mha(x, x, p, "self", causal=True)
            h = h + a
            x = L.layer_norm(h, p["cross_norm"], p["cross_norm_b"])
            a, (ck, cv) = self._mha(x, enc_out, p, "cross", causal=False)
            h = h + a
            x = L.layer_norm(h, p["mlp_norm"], p["mlp_norm_b"])
            h = h + L.gelu_mlp(x, p["mlp_w_in"], p["mlp_b_in"], p["mlp_w_out"], p["mlp_b_out"])
            return h, (sk, sv, ck, cv)

        h, caches = jax.lax.scan(self._remat(layer), h, stacked)
        h = L.layer_norm(h, params["dec_final_norm"], params["dec_final_bias"])
        return h, caches

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        """batch: frames (B, S_enc, d), tokens (B, S_dec), labels (B, S_dec)."""
        enc_out = self._encoder(params, batch["frames"])
        h, _ = self._decoder_full(params, batch["tokens"], enc_out)
        return L.chunked_cross_entropy(h, params["embed"].T, batch["labels"])

    def prefill(self, params, batch):
        enc_out = self._encoder(params, batch["frames"])
        h, (sk, sv, ck, cv) = self._decoder_full(params, batch["tokens"], enc_out)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["embed"].T,
                            preferred_element_type=jnp.float32)
        S = batch["tokens"].shape[1]
        cache = {
            "self_k": self._ws(sk, None, "batch", "sp", None, None),
            "self_v": self._ws(sv, None, "batch", "sp", None, None),
            "cross_k": self._ws(ck, None, "batch", "sp", None, None),
            "cross_v": self._ws(cv, None, "batch", "sp", None, None),
            "len": jnp.int32(S),
        }
        return logits, cache

    def decode_step(self, params, batch, cache):
        """One decoder token. cache: self_k/v (L,B,Smax,Kv,hd) growing,
        cross_k/v (L,B,S_enc,Kv,hd) fixed."""
        c = self.cfg
        B = batch["tokens"].shape[0]
        t = cache["len"]
        d = c.d_model
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = h + sinusoid_positions(1, d, offset=t).astype(h.dtype)[None]
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("dec.")}
        use_sp = self.mesh is not None and "model" in self.mesh.shape and self.mesh.shape["model"] > 1

        def layer(h, xs):
            p, skc, svc, ckc, cvc = xs
            # self attention against the growing cache
            x = L.layer_norm(h, p["self_norm"], p["self_norm_b"])
            q = jnp.einsum("bsd,dh->bsh", x, p["self_wq"]).reshape(B, 1, c.n_heads, c.hd)
            k = jnp.einsum("bsd,dh->bsh", x, p["self_wk"]).reshape(B, 1, c.n_kv_heads, c.hd)
            v = jnp.einsum("bsd,dh->bsh", x, p["self_wv"]).reshape(B, 1, c.n_kv_heads, c.hd)
            skc = jax.lax.dynamic_update_slice_in_dim(skc, k.astype(skc.dtype), t, axis=1)
            svc = jax.lax.dynamic_update_slice_in_dim(svc, v.astype(svc.dtype), t, axis=1)
            if use_sp:
                attn = L.decode_attention_sp(
                    q[:, 0], skc, svc, t + 1, mesh=self.mesh, sp_axis="model",
                    batch_axes=(safe_entry(self.mesh, self.rules, "batch", q.shape[0]),))[:, None]
            else:
                attn = L.attention(q, L.repeat_kv(skc, c.n_heads), L.repeat_kv(svc, c.n_heads),
                                   causal=True, q_offset=t)
            h = h + jnp.einsum("bsh,hd->bsd", attn.reshape(B, 1, -1), p["self_wo"])
            # cross attention against the fixed encoder cache
            x = L.layer_norm(h, p["cross_norm"], p["cross_norm_b"])
            q = jnp.einsum("bsd,dh->bsh", x, p["cross_wq"]).reshape(B, 1, c.n_heads, c.hd)
            if use_sp:
                ca = L.decode_attention_sp(
                    q[:, 0], ckc, cvc, jnp.int32(ckc.shape[1]), mesh=self.mesh,
                    sp_axis="model", batch_axes=(safe_entry(self.mesh, self.rules, "batch", q.shape[0]),))[:, None]
            else:
                ca = L.attention(q, L.repeat_kv(ckc, c.n_heads), L.repeat_kv(cvc, c.n_heads),
                                 causal=False)
            h = h + jnp.einsum("bsh,hd->bsd", ca.reshape(B, 1, -1), p["cross_wo"])
            x = L.layer_norm(h, p["mlp_norm"], p["mlp_norm_b"])
            h = h + L.gelu_mlp(x, p["mlp_w_in"], p["mlp_b_in"], p["mlp_w_out"], p["mlp_b_out"])
            return h, (skc, svc)

        h, (sks, svs) = jax.lax.scan(
            layer, h, (stacked, cache["self_k"], cache["self_v"],
                       cache["cross_k"], cache["cross_v"]))
        h = L.layer_norm(h, params["dec_final_norm"], params["dec_final_bias"])
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["embed"].T,
                            preferred_element_type=jnp.float32)
        cache = dict(cache, self_k=sks, self_v=svs, len=t + 1)
        return logits, cache

    def cache_templates(self, batch: int, seq: int) -> Dict[str, ParamSpec]:
        c = self.cfg
        kv = (c.n_layers, batch, seq, c.n_kv_heads, c.hd)
        axes = (None, "batch", "sp", None, None)
        return {
            "self_k": ParamSpec(kv, c.act_dtype, axes),
            "self_v": ParamSpec(kv, c.act_dtype, axes),
            "cross_k": ParamSpec(kv, c.act_dtype, axes),
            "cross_v": ParamSpec(kv, c.act_dtype, axes),
            "len": ParamSpec((), "int32", ()),
        }
