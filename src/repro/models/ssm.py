"""State-space models: Mamba-1 (falcon-mamba-7b) and Mamba-2/SSD hybrid (zamba2-2.7b).

TPU adaptation notes (DESIGN.md §3):

* Mamba-1's selective scan is elementwise-recurrent (VPU work, no MXU). We
  run it as an outer ``lax.scan`` over sequence chunks with an inner
  ``associative_scan`` — peak memory O(B·chunk·d_inner·d_state) per device
  instead of O(B·L·d_inner·d_state), and the chunk boundary states are the
  only saved activations under remat.
* Mamba-2 uses the SSD block decomposition: intra-chunk work becomes batched
  matmuls (MXU-friendly: (c×c) decay-masked attention-like products) and the
  inter-chunk recurrence is a tiny scan over chunk states. This is the
  TPU-native reformulation of the CUDA kernel in the Mamba-2 paper.
* zamba2 interleaves 6-layer Mamba-2 groups with ONE shared transformer block
  (same weights at every invocation — true weight sharing, 9 invocations for
  54 layers). Each invocation keeps its own KV cache.

Decode paths carry O(1) recurrent state (conv tail + SSM state) — the reason
these are the archs that run the long_500k cell.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.sharding.rules import ParamSpec, ShardingRules, named_sharding, safe_entry

__all__ = ["MambaLM", "Zamba2LM"]


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. x: (B, S, C); w: (C, K); b: (C,)."""
    K = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32).T[:, None, :],       # (K, 1, C) -> spec below
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[0],
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array):
    """Single-token depthwise conv. x_t: (B, C); conv_state: (B, K-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    out = (out + b.astype(jnp.float32)).astype(x_t.dtype)
    return out, window[:, 1:]


def _param_free_rms(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)).astype(x.dtype)


# ===========================================================================
# Mamba-1 (falcon-mamba-7b)
# ===========================================================================

class MambaLM:
    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None, remat_policy: str = "nothing"):
        assert cfg.ssm is not None and cfg.ssm.version == 1
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.remat_policy = remat_policy

    @property
    def dt_rank(self) -> int:
        c = self.cfg
        return c.ssm.dt_rank or -(-c.d_model // 16)

    def param_templates(self) -> Dict[str, ParamSpec]:
        c = self.cfg
        d, V, Ln = c.d_model, c.vocab, c.n_layers
        DI, N, K, R = c.d_inner, c.ssm.d_state, c.ssm.d_conv, self.dt_rank
        dt = c.param_dtype
        out_scale = 0.02 / (2 * Ln) ** 0.5
        t = {
            "embed": ParamSpec((V, d), dt, ("tp", None)),
            "final_norm": ParamSpec((d,), dt, (None,), init="ones"),
            "lm_head": ParamSpec((d, V), dt, ("fsdp", "tp")),
        }
        blk = {
            "norm": ParamSpec((Ln, d), dt, (None, None), init="ones", stacked=True),
            "in_proj": ParamSpec((Ln, d, 2 * DI), dt, (None, "fsdp", "tp"), stacked=True),
            "conv_w": ParamSpec((Ln, DI, K), dt, (None, "tp", None), stacked=True),
            "conv_b": ParamSpec((Ln, DI), dt, (None, "tp"), init="zeros", stacked=True),
            "x_proj": ParamSpec((Ln, DI, R + 2 * N), dt, (None, "tp", None), stacked=True),
            "dt_proj": ParamSpec((Ln, R, DI), dt, (None, None, "tp"), stacked=True),
            "dt_bias": ParamSpec((Ln, DI), dt, (None, "tp"), init="zeros", stacked=True),
            # A_log/D in fp32: the recurrence is numerically delicate
            "A_log": ParamSpec((Ln, DI, N), "float32", (None, "tp", None), init="ones", stacked=True),
            "D": ParamSpec((Ln, DI), "float32", (None, "tp"), init="ones", stacked=True),
            "out_proj": ParamSpec((Ln, DI, d), dt, (None, "tp", "fsdp"),
                                  init="scaled", init_scale=out_scale, stacked=True),
        }
        t.update({f"blocks.{k}": v for k, v in blk.items()})
        return t

    def param_count(self) -> int:
        n = 0
        for spec in self.param_templates().values():
            m = 1
            for s in spec.shape:
                m *= s
            n += m
        return n

    active_param_count = param_count

    def _ws(self, x, *axes):
        if self.mesh is None or self.rules is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, named_sharding(self.mesh, axes, self.rules, x.shape))

    def _remat(self, fn):
        if self.remat_policy == "none":
            return fn
        pol = {"nothing": jax.checkpoint_policies.nothing_saveable,
               "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable}[self.remat_policy]
        return jax.checkpoint(fn, policy=pol)

    # ------------------------------------------------------------------
    def _ssm_inputs(self, x, p):
        """x: (B, S, DI) post-conv. Returns dt (B,S,DI) f32, Bs/Cs (B,S,N) f32."""
        c = self.cfg
        N, R = c.ssm.d_state, self.dt_rank
        proj = jnp.einsum("bsd,dr->bsr", x, p["x_proj"]).astype(jnp.float32)
        dt_in, Bs, Cs = jnp.split(proj, [R, R + N], axis=-1)
        # falcon-mamba applies parameter-free RMS norm to dt/B/C streams
        dt_in, Bs, Cs = _param_free_rms(dt_in), _param_free_rms(Bs), _param_free_rms(Cs)
        dt = jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(jnp.float32))
        dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
        return dt, Bs, Cs

    def _selective_scan(self, x, dt, A, Bs, Cs, h0, chunk):
        """Chunked selective scan.

        x/dt: (B, S, DI) f32; A: (DI, N) f32 (negative); Bs/Cs: (B, S, N) f32;
        h0: (B, DI, N) f32. Returns (y (B, S, DI) f32, h_final).
        """
        B_, S, DI = x.shape
        N = A.shape[-1]
        chunk = min(chunk, S)
        while S % chunk:
            chunk //= 2
        nc = S // chunk
        xs = tuple(v.reshape(B_, nc, chunk, -1).swapaxes(0, 1) for v in (x, dt, Bs, Cs))

        def chunk_body(h, blk):
            xch, dtch, Bch, Cch = blk
            dA = dtch[..., None] * A                              # (B,c,DI,N)
            a = jnp.exp(dA)
            b = (dtch * xch)[..., None] * Bch[:, :, None, :]
            def comb(e1, e2):
                a1, b1 = e1
                a2, b2 = e2
                return a1 * a2, b1 * a2 + b2
            aP, bP = jax.lax.associative_scan(comb, (a, b), axis=1)
            hs = aP * h[:, None] + bP                             # (B,c,DI,N)
            y = jnp.einsum("bcdn,bcn->bcd", hs, Cch)
            return hs[:, -1], y

        h, ys = jax.lax.scan(self._remat(chunk_body), h0, xs)
        y = ys.swapaxes(0, 1).reshape(B_, S, DI)
        return y, h

    def _block_full(self, h, p):
        c = self.cfg
        B, S, _ = h.shape
        DI = c.d_inner
        x = L.rms_norm(h, p["norm"])
        xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
        xi, z = jnp.split(xz, 2, axis=-1)
        xi = self._ws(xi, "batch", None, "tp")
        xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
        dt, Bs, Cs = self._ssm_inputs(xi, p)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        h0 = jnp.zeros((B, DI, c.ssm.d_state), jnp.float32)
        y, _ = self._selective_scan(xi.astype(jnp.float32), dt, A, Bs, Cs, h0, c.ssm.chunk)
        y = y + p["D"].astype(jnp.float32) * xi.astype(jnp.float32)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
        return self._ws(h + out, "batch", None, None)

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        B, S = batch["tokens"].shape
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = self._ws(h, "batch", None, None)
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("blocks.")}

        def layer(h, p):
            return self._block_full(h, p), None

        h, _ = jax.lax.scan(self._remat(layer), h, stacked)
        h = L.rms_norm(h, params["final_norm"])
        return L.chunked_cross_entropy(h, params["lm_head"], batch["labels"])

    def prefill(self, params, batch):
        """Forward + final recurrent state per layer (the SSM 'cache')."""
        c = self.cfg
        B, S = batch["tokens"].shape
        DI, N, K = c.d_inner, c.ssm.d_state, c.ssm.d_conv
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("blocks.")}

        def layer(h, p):
            x = L.rms_norm(h, p["norm"])
            xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
            xi, z = jnp.split(xz, 2, axis=-1)
            # conv state = last K-1 PRE-conv inputs (what _conv_step consumes)
            conv_tail = xi[:, -(K - 1):, :] if K > 1 else xi[:, :0, :]
            xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
            dt, Bs, Cs = self._ssm_inputs(xi, p)
            A = -jnp.exp(p["A_log"].astype(jnp.float32))
            h0 = jnp.zeros((B, DI, N), jnp.float32)
            y, hN = self._selective_scan(xi.astype(jnp.float32), dt, A, Bs, Cs, h0, c.ssm.chunk)
            y = y + p["D"].astype(jnp.float32) * xi.astype(jnp.float32)
            y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype)
            out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
            return h + out, (hN, conv_tail)

        h, (hs, tails) = jax.lax.scan(layer, h, stacked)
        h = L.rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"],
                            preferred_element_type=jnp.float32)
        cache = {"ssm": hs, "conv": tails, "len": jnp.int32(S)}
        return logits, cache

    def decode_step(self, params, batch, cache):
        c = self.cfg
        B = batch["tokens"].shape[0]
        h = jnp.take(params["embed"], batch["tokens"], axis=0)  # (B,1,d)
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("blocks.")}

        def layer(h, xs):
            p, hst, conv_state = xs
            x = L.rms_norm(h, p["norm"])
            xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
            xi, z = jnp.split(xz, 2, axis=-1)                    # (B, DI)
            xi, conv_state = _conv_step(xi, conv_state, p["conv_w"], p["conv_b"])
            xi = jax.nn.silu(xi)
            dt, Bs, Cs = self._ssm_inputs(xi[:, None, :], p)
            dt, Bs, Cs = dt[:, 0], Bs[:, 0], Cs[:, 0]
            A = -jnp.exp(p["A_log"].astype(jnp.float32))
            xf = xi.astype(jnp.float32)
            hst = jnp.exp(dt[..., None] * A) * hst + (dt * xf)[..., None] * Bs[:, None, :]
            y = jnp.einsum("bdn,bn->bd", hst, Cs) + p["D"].astype(jnp.float32) * xf
            y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype)
            out = jnp.einsum("be,ed->bd", y, p["out_proj"])
            return h + out[:, None], (hst, conv_state)

        h, (hs, tails) = jax.lax.scan(layer, h, (stacked, cache["ssm"], cache["conv"]))
        h = L.rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"],
                            preferred_element_type=jnp.float32)
        return logits, {"ssm": hs, "conv": tails, "len": cache["len"] + 1}

    def cache_templates(self, batch: int, seq: int) -> Dict[str, ParamSpec]:
        c = self.cfg
        Ln, DI, N, K = c.n_layers, c.d_inner, c.ssm.d_state, c.ssm.d_conv
        return {
            "ssm": ParamSpec((Ln, batch, DI, N), "float32", (None, "batch", "tp", None)),
            "conv": ParamSpec((Ln, batch, K - 1, DI), c.act_dtype, (None, "batch", None, "tp")),
            "len": ParamSpec((), "int32", ()),
        }


# ===========================================================================
# Mamba-2 / SSD + shared-attention hybrid (zamba2-2.7b)
# ===========================================================================

class Zamba2LM:
    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None, remat_policy: str = "nothing"):
        assert cfg.ssm is not None and cfg.ssm.version == 2
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.remat_policy = remat_policy

    @property
    def n_ssm_heads(self) -> int:
        return self.cfg.d_inner // self.cfg.ssm.head_dim

    @property
    def n_groups(self) -> int:
        assert self.cfg.n_layers % self.cfg.attn_every == 0
        return self.cfg.n_layers // self.cfg.attn_every

    def param_templates(self) -> Dict[str, ParamSpec]:
        c = self.cfg
        d, V, Ln = c.d_model, c.vocab, c.n_layers
        DI, N, K = c.d_inner, c.ssm.d_state, c.ssm.d_conv
        P = self.n_ssm_heads
        hd, H, Kv, f = c.hd, c.n_heads, c.n_kv_heads, c.d_ff
        dt = c.param_dtype
        out_scale = 0.02 / (2 * Ln) ** 0.5
        t = {
            "embed": ParamSpec((V, d), dt, ("tp", None)),
            "final_norm": ParamSpec((d,), dt, (None,), init="ones"),
            "lm_head": ParamSpec((d, V), dt, ("fsdp", "tp")),
            # ---- ONE shared transformer block (9 invocations) ----
            "shared.attn_norm": ParamSpec((d,), dt, (None,), init="ones"),
            "shared.wq": ParamSpec((d, H * hd), dt, ("fsdp", "tp")),
            "shared.wk": ParamSpec((d, Kv * hd), dt, ("fsdp", "tp")),
            "shared.wv": ParamSpec((d, Kv * hd), dt, ("fsdp", "tp")),
            "shared.wo": ParamSpec((H * hd, d), dt, ("tp", "fsdp"),
                                   init="scaled", init_scale=out_scale),
            "shared.mlp_norm": ParamSpec((d,), dt, (None,), init="ones"),
            "shared.w_gate": ParamSpec((d, f), dt, ("fsdp", "tp")),
            "shared.w_up": ParamSpec((d, f), dt, ("fsdp", "tp")),
            "shared.w_down": ParamSpec((f, d), dt, ("tp", "fsdp"),
                                       init="scaled", init_scale=out_scale),
        }
        blk = {
            "norm": ParamSpec((Ln, d), dt, (None, None), init="ones", stacked=True),
            "in_proj_xz": ParamSpec((Ln, d, 2 * DI), dt, (None, "fsdp", "tp"), stacked=True),
            "in_proj_bcdt": ParamSpec((Ln, d, 2 * N + P), dt, (None, "fsdp", None), stacked=True),
            "conv_x_w": ParamSpec((Ln, DI, K), dt, (None, "tp", None), stacked=True),
            "conv_x_b": ParamSpec((Ln, DI), dt, (None, "tp"), init="zeros", stacked=True),
            "conv_bc_w": ParamSpec((Ln, 2 * N, K), dt, (None, None, None), stacked=True),
            "conv_bc_b": ParamSpec((Ln, 2 * N), dt, (None, None), init="zeros", stacked=True),
            "dt_bias": ParamSpec((Ln, P), "float32", (None, None), init="zeros", stacked=True),
            "A_log": ParamSpec((Ln, P), "float32", (None, None), init="ones", stacked=True),
            "D": ParamSpec((Ln, P), "float32", (None, None), init="ones", stacked=True),
            "gated_norm": ParamSpec((Ln, DI), dt, (None, "tp"), init="ones", stacked=True),
            "out_proj": ParamSpec((Ln, DI, d), dt, (None, "tp", "fsdp"),
                                  init="scaled", init_scale=out_scale, stacked=True),
        }
        t.update({f"blocks.{k}": v for k, v in blk.items()})
        return t

    def param_count(self) -> int:
        n = 0
        for spec in self.param_templates().values():
            m = 1
            for s in spec.shape:
                m *= s
            n += m
        return n

    active_param_count = param_count

    def _ws(self, x, *axes):
        if self.mesh is None or self.rules is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, named_sharding(self.mesh, axes, self.rules, x.shape))

    def _remat(self, fn):
        if self.remat_policy == "none":
            return fn
        pol = {"nothing": jax.checkpoint_policies.nothing_saveable,
               "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable}[self.remat_policy]
        return jax.checkpoint(fn, policy=pol)

    # ------------------------------------------------------------------
    # SSD (Mamba-2) chunked scan — matmul formulation
    # ------------------------------------------------------------------
    def _ssd(self, x, dt, A, Bs, Cs, h0, chunk):
        """x: (B,S,P,hd) f32; dt: (B,S,P) f32 (softplus'd); A: (P,) f32 neg;
        Bs/Cs: (B,S,N) f32 (single group, broadcast over heads);
        h0: (B,P,N,hd) f32. Returns (y (B,S,P,hd), h_final)."""
        B_, S, P, hd = x.shape
        N = Bs.shape[-1]
        chunk = min(chunk, S)
        while S % chunk:
            chunk //= 2
        nc = S // chunk
        xc = x.reshape(B_, nc, chunk, P, hd).swapaxes(0, 1)
        dtc = dt.reshape(B_, nc, chunk, P).swapaxes(0, 1)
        Bc = Bs.reshape(B_, nc, chunk, N).swapaxes(0, 1)
        Cc = Cs.reshape(B_, nc, chunk, N).swapaxes(0, 1)

        def chunk_body(h, blk):
            xch, dtch, Bch, Cch = blk                 # (B,c,P,hd) (B,c,P) (B,c,N)
            dA = dtch * A                             # (B,c,P), negative
            s = jnp.cumsum(dA, axis=1)                # log-decay from chunk start
            # intra-chunk: attention-like masked product
            CB = jnp.einsum("bin,bjn->bij", Cch, Bch)             # (B,c,c)
            Lmask = s[:, :, None, :] - s[:, None, :, :]           # s_i - s_j (B,i,j,P)
            tri = jnp.tril(jnp.ones((chunk, chunk), bool))
            M = jnp.where(tri[None, :, :, None], jnp.exp(Lmask), 0.0)
            M = M * CB[..., None] * dtch[:, None, :, :]           # × dt_j
            y = jnp.einsum("bijp,bjph->biph", M, xch)
            # inter-chunk: contribution of carry state
            y = y + jnp.einsum("bin,bpnh,bip->biph", Cch, h, jnp.exp(s))
            # state update
            decay_to_end = jnp.exp(s[:, -1:, :] - s)              # (B,c,P)
            S_chunk = jnp.einsum("bjp,bjn,bjph->bpnh", decay_to_end * dtch, Bch, xch)
            h_new = jnp.exp(s[:, -1, :])[:, :, None, None] * h + S_chunk
            return h_new, y

        h, ys = jax.lax.scan(self._remat(chunk_body), h0, (xc, dtc, Bc, Cc))
        y = ys.swapaxes(0, 1).reshape(B_, S, P, hd)
        return y, h

    def _mamba_inputs(self, x_conv, bcdt_conv, p):
        """Split conv'd streams into SSD inputs (f32)."""
        c = self.cfg
        N = c.ssm.d_state
        P = self.n_ssm_heads
        hd = c.ssm.head_dim
        B_, S, _ = x_conv.shape
        x = x_conv.astype(jnp.float32).reshape(B_, S, P, hd)
        Bs, Cs = jnp.split(bcdt_conv.astype(jnp.float32), 2, axis=-1)
        return x, Bs, Cs

    def _mamba_block(self, h, p, h0=None, conv_states=None, single_step=False):
        """One Mamba-2 block. Full-sequence when single_step=False."""
        c = self.cfg
        N, K, P, hd = c.ssm.d_state, c.ssm.d_conv, self.n_ssm_heads, c.ssm.head_dim
        DI = c.d_inner
        B_ = h.shape[0]
        x = L.rms_norm(h, p["norm"])
        xz = jnp.einsum("bsd,de->bse", x, p["in_proj_xz"])
        bcdt = jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"])
        xi, z = jnp.split(xz, 2, axis=-1)
        bc, dt_in = jnp.split(bcdt, [2 * N], axis=-1)             # (B,S,2N), (B,S,P)
        A = -jnp.exp(p["A_log"])
        if single_step:
            cx, cbc = conv_states
            xi1, cx = _conv_step(xi[:, 0], cx, p["conv_x_w"], p["conv_x_b"])
            bc1, cbc = _conv_step(bc[:, 0], cbc, p["conv_bc_w"], p["conv_bc_b"])
            xi1 = jax.nn.silu(xi1)[:, None]
            bc1 = jax.nn.silu(bc1)[:, None]
            xs, Bs, Cs = self._mamba_inputs(xi1, bc1, p)
            dt = jax.nn.softplus(dt_in[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,P)
            dA = jnp.exp(dt * A)                                   # (B,P)
            hN = dA[:, :, None, None] * h0 + jnp.einsum(
                "bp,bn,bph->bpnh", dt, Bs[:, 0], xs[:, 0])
            y = jnp.einsum("bn,bpnh->bph", Cs[:, 0], hN)[:, None]  # (B,1,P,hd)
            x_for_D = xs
            new_conv = (cx, cbc)
        else:
            xi = jax.nn.silu(_causal_conv(xi, p["conv_x_w"], p["conv_x_b"]))
            bc = jax.nn.silu(_causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"]))
            xs, Bs, Cs = self._mamba_inputs(xi, bc, p)
            dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])
            if h0 is None:
                h0 = jnp.zeros((B_, P, N, hd), jnp.float32)
            y, hN = self._ssd(xs, dt, A, Bs, Cs, h0, c.ssm.chunk)
            x_for_D = xs
            new_conv = None
        y = y + p["D"][:, None] * x_for_D                          # (B,S,P,hd)
        S_ = y.shape[1]
        y = y.reshape(B_, S_, DI)
        y = (y * jax.nn.silu(z.astype(jnp.float32)[:, :S_]))
        y = L.rms_norm(y.astype(h.dtype), p["gated_norm"])
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
        return h + out, hN, new_conv

    # ------------------------------------------------------------------
    def _shared_attn(self, h, params, positions, cache=None, t=None):
        """The shared transformer block. Full-seq when cache is None; else
        one-token decode against this invocation's cache slice."""
        c = self.cfg
        B = h.shape[0]
        x = L.rms_norm(h, params["shared.attn_norm"])
        S = x.shape[1]
        q = jnp.einsum("bsd,dh->bsh", x, params["shared.wq"]).reshape(B, S, c.n_heads, c.hd)
        k = jnp.einsum("bsd,dh->bsh", x, params["shared.wk"]).reshape(B, S, c.n_kv_heads, c.hd)
        v = jnp.einsum("bsd,dh->bsh", x, params["shared.wv"]).reshape(B, S, c.n_kv_heads, c.hd)
        q = L.apply_rope(q, positions, c.rope_theta)
        k = L.apply_rope(k, positions, c.rope_theta)
        if cache is None:
            kH, vH = L.repeat_kv(k, c.n_heads), L.repeat_kv(v, c.n_heads)
            attn = L.attention(q, kH, vH, causal=True)
            new_cache = (k, v)
        else:
            k_cache, v_cache = cache
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), t, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), t, axis=1)
            if self.mesh is not None and "model" in self.mesh.shape and self.mesh.shape["model"] > 1:
                attn = L.decode_attention_sp(
                    q[:, 0], k_cache, v_cache, t + 1, mesh=self.mesh,
                    sp_axis="model", batch_axes=(safe_entry(self.mesh, self.rules, "batch", q.shape[0]),))[:, None]
            else:
                kH, vH = L.repeat_kv(k_cache, c.n_heads), L.repeat_kv(v_cache, c.n_heads)
                attn = L.attention(q, kH, vH, causal=True, q_offset=t)
            new_cache = (k_cache, v_cache)
        h = h + jnp.einsum("bsh,hd->bsd", attn.reshape(B, S, -1), params["shared.wo"])
        x = L.rms_norm(h, params["shared.mlp_norm"])
        h = h + L.swiglu(x, params["shared.w_gate"], params["shared.w_up"], params["shared.w_down"])
        return h, new_cache

    # ------------------------------------------------------------------
    def _split_groups(self, params):
        g = self.cfg.attn_every
        stacked = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith("blocks.")}
        return [
            {k: v[i * g:(i + 1) * g] for k, v in stacked.items()}
            for i in range(self.n_groups)
        ]

    def loss(self, params, batch):
        B, S = batch["tokens"].shape
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = self._ws(h, "batch", None, None)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def mamba_layer(h, p):
            h, _, _ = self._mamba_block(h, p)
            return h, None

        for grp in self._split_groups(params):
            h, _ = jax.lax.scan(self._remat(mamba_layer), h, grp)
            h, _ = self._shared_attn(h, params, positions)
        h = L.rms_norm(h, params["final_norm"])
        return L.chunked_cross_entropy(h, params["lm_head"], batch["labels"])

    def prefill(self, params, batch):
        c = self.cfg
        B, S = batch["tokens"].shape
        N, K, P, hd = c.ssm.d_state, c.ssm.d_conv, self.n_ssm_heads, c.ssm.head_dim
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        ssm_states, conv_x, conv_bc, attn_k, attn_v = [], [], [], [], []

        def mamba_layer(h, p):
            # conv states = last K-1 PRE-conv inputs of the x and BC streams
            x = L.rms_norm(h, p["norm"])
            xz = jnp.einsum("bsd,de->bse", x, p["in_proj_xz"])
            xi = jnp.split(xz, 2, axis=-1)[0]
            bc = jnp.split(jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"]), [2 * N], axis=-1)[0]
            h_out, hN, _ = self._mamba_block(h, p)
            tail_x = xi[:, -(K - 1):, :]
            tail_bc = bc[:, -(K - 1):, :]
            return h_out, (hN, tail_x, tail_bc)

        for grp in self._split_groups(params):
            h, (hNs, tx, tbc) = jax.lax.scan(mamba_layer, h, grp)
            h, (k, v) = self._shared_attn(h, params, positions)
            ssm_states.append(hNs)
            conv_x.append(tx)
            conv_bc.append(tbc)
            attn_k.append(k)
            attn_v.append(v)
        h = L.rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"],
                            preferred_element_type=jnp.float32)
        cache = {
            "ssm": jnp.concatenate(ssm_states, 0),
            "conv_x": jnp.concatenate(conv_x, 0),
            "conv_bc": jnp.concatenate(conv_bc, 0),
            "attn_k": jnp.stack(attn_k),
            "attn_v": jnp.stack(attn_v),
            "len": jnp.int32(S),
        }
        return logits, cache

    def decode_step(self, params, batch, cache):
        c = self.cfg
        B = batch["tokens"].shape[0]
        t = cache["len"]
        h = jnp.take(params["embed"], batch["tokens"], axis=0)   # (B,1,d)
        positions = jnp.full((B, 1), t, jnp.int32)
        g = c.attn_every

        def mamba_layer(h, xs):
            p, h0, cx, cbc = xs
            h, hN, (cx, cbc) = self._mamba_block(h, p, h0=h0, conv_states=(cx, cbc),
                                                 single_step=True)
            return h, (hN, cx, cbc)

        new_ssm, new_cx, new_cbc, new_k, new_v = [], [], [], [], []
        for i, grp in enumerate(self._split_groups(params)):
            sl = slice(i * g, (i + 1) * g)
            h, (hNs, cxs, cbcs) = jax.lax.scan(
                mamba_layer, h,
                (grp, cache["ssm"][sl], cache["conv_x"][sl], cache["conv_bc"][sl]))
            h, (k, v) = self._shared_attn(
                h, params, positions, cache=(cache["attn_k"][i], cache["attn_v"][i]), t=t)
            new_ssm.append(hNs)
            new_cx.append(cxs)
            new_cbc.append(cbcs)
            new_k.append(k)
            new_v.append(v)
        h = L.rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"],
                            preferred_element_type=jnp.float32)
        cache = {
            "ssm": jnp.concatenate(new_ssm, 0),
            "conv_x": jnp.concatenate(new_cx, 0),
            "conv_bc": jnp.concatenate(new_cbc, 0),
            "attn_k": jnp.stack(new_k),
            "attn_v": jnp.stack(new_v),
            "len": t + 1,
        }
        return logits, cache

    def cache_templates(self, batch: int, seq: int) -> Dict[str, ParamSpec]:
        c = self.cfg
        Ln, N, K, P, hd = c.n_layers, c.ssm.d_state, c.ssm.d_conv, self.n_ssm_heads, c.ssm.head_dim
        return {
            "ssm": ParamSpec((Ln, batch, P, N, hd), "float32", (None, "batch", "tp", None, None)),
            "conv_x": ParamSpec((Ln, batch, K - 1, c.d_inner), c.act_dtype, (None, "batch", None, "tp")),
            "conv_bc": ParamSpec((Ln, batch, K - 1, 2 * N), c.act_dtype, (None, "batch", None, None)),
            "attn_k": ParamSpec((self.n_groups, batch, seq, c.n_kv_heads, c.hd),
                                c.act_dtype, (None, "batch", "sp", None, None)),
            "attn_v": ParamSpec((self.n_groups, batch, seq, c.n_kv_heads, c.hd),
                                c.act_dtype, (None, "batch", "sp", None, None)),
            "len": ParamSpec((), "int32", ()),
        }
