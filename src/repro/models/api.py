"""Unified model API: dispatch ArchConfig -> model class, parameter init,
ShapeDtypeStruct stand-ins, and input specs for every (arch × shape) cell.

The dry-run never allocates: ``abstract_params`` / ``abstract_cache`` /
``abstract_inputs`` return ShapeDtypeStructs; the smoke tests and examples use
``init_params`` / ``make_batch`` with real (reduced-config) arrays.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ArchConfig, ShapeCell
from repro.sharding.rules import ParamSpec, ShardingRules, logical_to_spec

__all__ = [
    "get_model",
    "init_params",
    "abstract_params",
    "param_shardings",
    "abstract_cache",
    "cache_shardings",
    "input_templates",
    "abstract_inputs",
    "input_shardings",
    "make_batch",
]


def get_model(cfg: ArchConfig, mesh: Optional[Mesh] = None,
              rules: Optional[ShardingRules] = None, remat_policy: str = "nothing"):
    from repro.models.ssm import MambaLM, Zamba2LM
    from repro.models.transformer import TransformerLM
    from repro.models.whisper import WhisperModel

    if cfg.family == "encdec":
        return WhisperModel(cfg, mesh, rules, remat_policy)
    if cfg.family == "ssm":
        return MambaLM(cfg, mesh, rules, remat_policy)
    if cfg.family == "hybrid":
        return Zamba2LM(cfg, mesh, rules, remat_policy)
    return TransformerLM(cfg, mesh, rules, remat_policy)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _init_one(key, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    scale = spec.init_scale if spec.init == "scaled" else 0.02
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, jax.Array]:
    model = get_model(cfg)
    templates = model.param_templates()
    keys = jax.random.split(key, len(templates))
    return {name: _init_one(k, spec) for k, (name, spec) in zip(keys, sorted(templates.items()))}


def abstract_params(cfg: ArchConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    return {name: spec.sds for name, spec in get_model(cfg).param_templates().items()}


def param_shardings(cfg: ArchConfig, mesh: Mesh, rules: ShardingRules) -> Dict[str, NamedSharding]:
    return {name: spec.sharding(mesh, rules)
            for name, spec in get_model(cfg).param_templates().items()}


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def abstract_cache(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    return {k: v.sds for k, v in get_model(cfg).cache_templates(batch, seq).items()}


def cache_shardings(cfg: ArchConfig, batch: int, seq: int, mesh: Mesh,
                    rules: ShardingRules) -> Dict[str, NamedSharding]:
    return {k: v.sharding(mesh, rules)
            for k, v in get_model(cfg).cache_templates(batch, seq).items()}


def init_cache(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, jax.Array]:
    out = {}
    for k, spec in get_model(cfg).cache_templates(batch, seq).items():
        out[k] = jnp.zeros(spec.shape, spec.dtype)
    out["len"] = jnp.int32(0)
    return out


# ---------------------------------------------------------------------------
# Model inputs per shape cell
# ---------------------------------------------------------------------------

def input_templates(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, ParamSpec]:
    """ShapeDtypeStruct templates (with logical axes) for one cell's batch.

    train:   tokens + labels (B, S)  [+ frames/patch_embeds/positions3 stubs]
    prefill: tokens (B, S)           [+ stubs]
    decode:  tokens (B, 1)           (the cache is a separate argument)
    """
    B = cell.global_batch
    S = 1 if cell.kind == "decode" else cell.seq_len
    t: Dict[str, ParamSpec] = {
        "tokens": ParamSpec((B, S), "int32", ("batch", None)),
    }
    if cell.kind == "train":
        t["labels"] = ParamSpec((B, S), "int32", ("batch", None))
    if cfg.family == "encdec" and cell.kind != "decode":
        # conv-frontend stub: precomputed frame embeddings
        t["frames"] = ParamSpec((B, cell.seq_len, cfg.d_model), cfg.act_dtype,
                                ("batch", None, None))
    if cfg.family == "vlm":
        if cell.kind != "decode":
            # patch-embedding stub merged additively over token embeddings
            t["patch_embeds"] = ParamSpec((B, S, cfg.d_model), cfg.act_dtype,
                                          ("batch", None, None))
            t["positions3"] = ParamSpec((B, 3, S), "int32", ("batch", None, None))
    return t


def abstract_inputs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    return {k: v.sds for k, v in input_templates(cfg, cell).items()}


def input_shardings(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
                    rules: ShardingRules) -> Dict[str, NamedSharding]:
    return {k: v.sharding(mesh, rules) for k, v in input_templates(cfg, cell).items()}


def make_batch(cfg: ArchConfig, cell: ShapeCell, key: jax.Array) -> Dict[str, jax.Array]:
    """Real synthetic batch for smoke tests / examples (reduced configs)."""
    out = {}
    for name, spec in input_templates(cfg, cell).items():
        key, sub = jax.random.split(key)
        if spec.dtype == "int32":
            if name == "positions3":
                S = spec.shape[-1]
                pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), spec.shape)
                out[name] = pos
            else:
                out[name] = jax.random.randint(sub, spec.shape, 0, cfg.vocab, jnp.int32)
        else:
            out[name] = (jax.random.normal(sub, spec.shape, jnp.float32) * 0.02).astype(spec.dtype)
    return out
