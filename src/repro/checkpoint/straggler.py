"""Straggler mitigation for storage-side work: speculative re-issue.

``speculative_map`` runs independent tasks on a worker pool; any task that has
not completed within ``timeout`` seconds is speculatively re-issued to a spare
worker (both attempts race; first completion wins, results are idempotent by
construction — writes go to distinct tmp files and rename atomically). This is
the classic tail-latency defence for checkpoint shard writers hitting a slow
disk/object-store connection.

The trainer's other straggler defences live elsewhere: host data prefetch
(``repro.data``), write-behind async checkpointing (``repro.checkpoint``).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable, List, Sequence, TypeVar

__all__ = ["speculative_map"]

T = TypeVar("T")
R = TypeVar("R")


def speculative_map(fn: Callable[[T], R], items: Sequence[T], *,
                    timeout: float = 30.0, workers: int = 4,
                    max_attempts: int = 3) -> List[R]:
    """Map ``fn`` over ``items`` with speculative re-execution of stragglers.

    Returns results in input order. Raises the task's exception if every
    attempt of a task fails.
    """
    results: dict = {}
    errors: dict = {}
    lock = threading.Lock()

    def run_one(idx: int, item: T):
        try:
            r = fn(item)
            with lock:
                results.setdefault(idx, r)
        except BaseException as e:  # recorded; a speculative retry may still win
            with lock:
                errors.setdefault(idx, []).append(e)

    with ThreadPoolExecutor(max_workers=workers) as pool:
        attempts = {i: 1 for i in range(len(items))}
        futures = {pool.submit(run_one, i, it): i for i, it in enumerate(items)}
        deadline = {i: time.monotonic() + timeout for i in range(len(items))}
        pending = set(futures)
        while pending:
            done, pending = wait(pending, timeout=0.05, return_when=FIRST_COMPLETED)
            now = time.monotonic()
            with lock:
                missing = [i for i in range(len(items))
                           if i not in results and len(errors.get(i, [])) < attempts[i]]
            # re-issue overdue tasks
            for i in list(missing):
                if now > deadline[i] and attempts[i] < max_attempts:
                    attempts[i] += 1
                    deadline[i] = now + timeout
                    f = pool.submit(run_one, i, items[i])
                    pending.add(f)
            with lock:
                if len(results) == len(items):
                    break
                hard_failed = [i for i in range(len(items))
                               if i not in results and len(errors.get(i, [])) >= max_attempts]
            if hard_failed:
                raise errors[hard_failed[0]][-1]
            if not pending and len(results) < len(items):
                # all futures drained; re-issue whatever is missing
                with lock:
                    todo = [i for i in range(len(items)) if i not in results]
                for i in todo:
                    if attempts[i] >= max_attempts:
                        raise errors.get(i, [RuntimeError(f"task {i} lost")])[-1]
                    attempts[i] += 1
                    pending.add(pool.submit(run_one, i, items[i]))
    return [results[i] for i in range(len(items))]
