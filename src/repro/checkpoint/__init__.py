"""checkpoint subsystem."""
