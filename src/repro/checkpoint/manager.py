"""Checkpoint manager: atomic safetensors checkpoints stored THROUGH the zLLM
pipeline — the paper's storage layer as a first-class training-framework
feature.

Every checkpoint of a run is a same-family variant of the run's first
checkpoint (exactly the structure the paper exploits for fine-tuned models),
so the manager:

* serializes the params (+ optionally optimizer state) to one safetensors
  file in insertion order (tmp + fsync + rename = atomic commit; a manifest
  records step + content hash),
* ingests it into a ``ZLLMStore`` — FileDedup across identical saves,
  TensorDedup across steps (frozen tensors are zero-payload), BitX against
  the run's base checkpoint,
* optionally drops the plain file afterwards (``keep_plain=False``) so the
  run directory holds only the compressed containers,
* restores ELASTICALLY: tensors are stored unsharded, so a checkpoint taken
  on a 16×16 mesh restores onto any other mesh / device count via
  ``jax.device_put`` with the new shardings.

``save_async`` moves serialization+ingest off the training thread (the step
only blocks on the previous save's completion — single-buffered write-behind).
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.dedup import sha256_bytes
from repro.core.pipeline import ZLLMStore
from repro.formats import safetensors as st

__all__ = ["CheckpointManager"]

_ML_BF16 = None


def _to_numpy(x) -> Tuple[np.ndarray, Optional[str]]:
    """Host array + optional safetensors dtype-tag override (for bf16)."""
    global _ML_BF16
    arr = np.asarray(x)
    if arr.dtype.name == "bfloat16":
        return arr.view(np.uint16), "BF16"
    return arr, None


class CheckpointManager:
    def __init__(self, run_dir: str, *, store: Optional[ZLLMStore] = None,
                 run_id: str = "run", keep_plain: bool = True,
                 save_optimizer: bool = True):
        self.run_dir = run_dir
        os.makedirs(run_dir, exist_ok=True)
        self.store = store
        self.run_id = run_id
        self.keep_plain = keep_plain
        self.save_optimizer = save_optimizer
        self._async_thread: Optional[threading.Thread] = None
        self._async_err: Optional[BaseException] = None
        # first checkpoint of the run = BitX base; a RESUMED run rediscovers
        # its base from the store so post-resume checkpoints keep chaining
        self._base_key: Optional[str] = None
        if store is not None:
            self._base_key = store.base_key_of.get(run_id)

    # ------------------------------------------------------------------
    def _flatten(self, params: Dict, opt_state: Optional[Dict]) -> Dict[str, Any]:
        flat = {f"params/{k}": v for k, v in params.items()}
        if opt_state is not None and self.save_optimizer:
            import jax
            leaves = jax.tree_util.tree_leaves_with_path(opt_state)
            for path, leaf in leaves:
                key = "opt/" + "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
                flat[key] = leaf
        return flat

    def _unflatten(self, flat: Dict[str, np.ndarray], opt_template=None):
        params = {k[len("params/"):]: v for k, v in flat.items() if k.startswith("params/")}
        opt = None
        if opt_template is not None:
            import jax
            leaves_p = jax.tree_util.tree_leaves_with_path(opt_template)
            vals = []
            for path, leaf in leaves_p:
                key = "opt/" + "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
                v = flat[key]
                if hasattr(leaf, "dtype") and np.asarray(leaf).dtype != v.dtype:
                    v = v.astype(np.asarray(leaf).dtype)
                vals.append(v)
            opt = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(opt_template), vals)
        return params, opt

    # ------------------------------------------------------------------
    def ckpt_path(self, step: int) -> str:
        return os.path.join(self.run_dir, f"checkpoint-{step:08d}.safetensors")

    def save(self, step: int, params: Dict, opt_state: Optional[Dict] = None) -> str:
        flat = self._flatten(params, opt_state)
        tensors, tags = {}, {}
        for k, v in flat.items():
            arr, tag = _to_numpy(v)
            tensors[k] = arr
            if tag:
                tags[k] = tag
        path = self.ckpt_path(step)
        st.save_file(tensors, path, metadata={"step": str(step), "run_id": self.run_id},
                     dtype_tags=tags)
        digest = sha256_bytes(open(path, "rb").read())
        manifest = {"step": step, "file": os.path.basename(path), "sha256": digest,
                    "time": time.time()}
        mpath = os.path.join(self.run_dir, "manifest.json")
        entries = []
        if os.path.exists(mpath):
            entries = json.load(open(mpath))
        entries = [e for e in entries if e["step"] != step] + [manifest]
        tmp = mpath + ".tmp"
        with open(tmp, "w") as f:
            json.dump(sorted(entries, key=lambda e: e["step"]), f, indent=1)
        os.replace(tmp, mpath)

        if self.store is not None:
            fname = f"checkpoint-{step:08d}.safetensors"
            self.store.ingest_file(path, self.run_id, fname,
                                   declared_base=self._base_key)
            if self._base_key is None:
                self._base_key = f"{self.run_id}/{fname}"
            if not self.keep_plain:
                os.remove(path)
        return path

    def save_async(self, step: int, params: Dict, opt_state: Optional[Dict] = None):
        """Write-behind save. Blocks only if the previous save is still running."""
        self.wait()
        import jax
        # snapshot to host BEFORE returning control (params may be donated/updated)
        host_params = {k: np.asarray(v) for k, v in params.items()}
        host_opt = jax.tree.map(np.asarray, opt_state) if opt_state is not None else None

        def work():
            try:
                self.save(step, host_params, host_opt)
            except BaseException as e:  # surfaced on next wait()
                self._async_err = e

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err is not None:
            err, self._async_err = self._async_err, None
            raise err

    # ------------------------------------------------------------------
    def steps(self):
        mpath = os.path.join(self.run_dir, "manifest.json")
        if not os.path.exists(mpath):
            return []
        return [e["step"] for e in json.load(open(mpath))]

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return max(s) if s else None

    def restore(self, step: Optional[int] = None, opt_template=None,
                verify: bool = True):
        """Returns (step, params numpy dict, opt_state or None). Reads the
        plain file when kept, else reconstructs from the zLLM store."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        fname = f"checkpoint-{step:08d}.safetensors"
        path = self.ckpt_path(step)
        if not os.path.exists(path):
            assert self.store is not None, "no plain file and no store"
            data = self.store.retrieve_file(self.run_id, fname, verify=verify)
            tmp = path + ".restore"
            with open(tmp, "w+b") as f:
                f.write(data)
            flat = st.load_file(tmp)
            infos, _, _ = st.read_header(tmp)
            os.remove(tmp)
        else:
            flat = st.load_file(path)
            infos, _, _ = st.read_header(path)
        # re-tag BF16 views
        tag_by_name = {ti.name: ti.dtype_str for ti in infos}
        out = {}
        for k, v in flat.items():
            if tag_by_name.get(k) == "BF16":
                import ml_dtypes
                v = v.view(ml_dtypes.bfloat16)
            out[k] = v
        params, opt = self._unflatten(out, opt_template)
        return step, params, opt

    def restore_sharded(self, mesh, shardings: Dict, step: Optional[int] = None,
                        opt_template=None, opt_shardings=None):
        """Elastic restore: device_put host tensors with NEW shardings (any mesh)."""
        import jax
        step, params, opt = self.restore(step, opt_template)
        if params is None:
            return None, None, None
        params = {k: jax.device_put(v, shardings[k]) for k, v in params.items()}
        if opt is not None and opt_shardings is not None:
            opt = jax.tree.map(lambda x, s: jax.device_put(x, s), opt, opt_shardings)
        return step, params, opt
