"""Architecture + input-shape configuration system.

``ArchConfig`` is a frozen dataclass describing one architecture; each of the
10 assigned architectures gets one module in this package exporting ``CONFIG``
(the exact published config) and ``SMOKE`` (a reduced same-family variant for
CPU smoke tests). ``ShapeCell`` describes one assigned input-shape cell.

The registry (`repro.configs.registry`) resolves ``--arch <id>`` strings.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["MoEConfig", "SSMConfig", "ArchConfig", "ShapeCell", "SHAPES"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    version: int = 1            # 1 = Mamba-1 (falcon-mamba), 2 = Mamba-2/SSD (zamba2)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64          # Mamba-2 only
    dt_rank: int = 0            # Mamba-1: ceil(d_model / 16) when 0
    chunk: int = 256            # scan chunk length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rope_pct: float = 1.0
    mrope: bool = False         # Qwen2-VL M-RoPE
    moe: Optional[MoEConfig] = None
    swa_window: int = 0         # sliding-window attention (Mixtral)
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0         # hybrid: shared attention block every N ssm layers
    tie_embeddings: bool = False
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act_dtype: str = "bfloat16"
    attn_score_dtype: str = "float32"  # bf16 halves flash score-block HBM traffic
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"    # adamw | adafactor (grok-scale)
    fsdp_over_pod: bool = False # shard params over ("pod","data") on the multi-pod mesh
    # which shape cells apply
    supports_decode: bool = True
    supports_long: bool = False # sub-quadratic attention -> run long_500k
    long_skip_reason: str = ""
    source: str = ""            # [arXiv/hf; verification tier]

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    def param_count(self) -> int:
        """Exact parameter count from the template table."""
        from repro.models.api import get_model
        return get_model(self).param_count()

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k of E experts)."""
        from repro.models.api import get_model
        return get_model(self).active_param_count()


@dataclass(frozen=True)
class ShapeCell:
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1       # grad-accum steps (train only)

    def with_microbatches(self, n: int) -> "ShapeCell":
        return replace(self, microbatches=n)


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256, microbatches=8),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cells_for(cfg: ArchConfig):
    """The applicable (arch × shape) cells: long_500k only for sub-quadratic
    archs; decode only for archs with a decode step (all assigned archs have
    one — whisper is enc-dec, not encoder-only)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.supports_long:
            continue
        if s.kind == "decode" and not cfg.supports_decode:
            continue
        out.append(s)
    return out
