"""falcon-mamba-7b — attention-free Mamba-1: 64L d_model=4096 vocab=65024,
ssm_state=16, d_inner=2*d_model, parameter-free RMS norm on dt/B/C streams.
[arXiv:2410.05355; unverified]

O(1) recurrent decode state: runs the long_500k cell."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024,
    ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2, chunk=128),
    supports_long=True,
    source="[arXiv:2410.05355; unverified]",
)

SMOKE = ArchConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=256,
    ssm=SSMConfig(version=1, d_state=4, d_conv=4, expand=2, chunk=8),
    supports_long=True,
)
