"""phi4-mini-3.8b — dense: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE (partial rotary) SwiGLU GQA. [arXiv:2412.08905; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064,
    rope_theta=1e4, rope_pct=0.75, tie_embeddings=True,
    supports_long=False, long_skip_reason="full attention, quadratic in seq",
    source="[arXiv:2412.08905; hf]",
)

SMOKE = ArchConfig(
    name="phi4-mini-3.8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, rope_theta=1e4, rope_pct=0.75, tie_embeddings=True,
    supports_long=False,
)
