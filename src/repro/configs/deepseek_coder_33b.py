"""deepseek-coder-33b — dense llama-arch: 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256. [arXiv:2401.14196; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256,
    rope_theta=1e5,
    supports_long=False, long_skip_reason="full attention, quadratic in seq",
    source="[arXiv:2401.14196; hf]",
)

SMOKE = ArchConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, rope_theta=1e5,
    supports_long=False,
)
