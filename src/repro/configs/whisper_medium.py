"""whisper-medium — encoder-decoder audio backbone: 24+24L d_model=1024
16H (MHA kv=16) d_ff=4096 vocab=51865, conv frontend STUBBED (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    norm="layernorm", tie_embeddings=True,
    supports_long=False, long_skip_reason="full attention, quadratic in seq",
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = ArchConfig(
    name="whisper-medium-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, norm="layernorm", tie_embeddings=True,
    supports_long=False,
)
