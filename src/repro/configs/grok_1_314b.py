"""grok-1-314b — MoE: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
8 experts top-2. [hf:xai-org/grok-1; unverified]

Uses Adafactor (factored second moment): 314B params x Adam fp32 moments do
not fit the per-device HBM budget at 256 chips."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    rope_theta=1e4,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
    optimizer="adafactor", fsdp_over_pod=True,
    supports_long=False, long_skip_reason="full attention, quadratic in seq",
    source="[hf:xai-org/grok-1; unverified]",
)

SMOKE = ArchConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, rope_theta=1e4,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=2.0),
    optimizer="adafactor",
    supports_long=False,
)
