"""mixtral-8x7b — MoE: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
8 experts top-2, sliding-window attention (W=4096). [arXiv:2401.04088; hf]

SWA makes decode memory O(W): the long_500k cell runs with a rolling
window cache."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
    swa_window=4096,
    supports_long=True,
    source="[arXiv:2401.04088; hf]",
)

SMOKE = ArchConfig(
    name="mixtral-8x7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, rope_theta=1e6,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=2.0),
    swa_window=16,
    supports_long=True,
)
