"""zamba2-2.7b — hybrid: 54 Mamba-2 layers d_model=2560 + ONE shared
transformer block (32H GQA kv=32 d_ff=10240) invoked every 6 layers,
ssm_state=64, vocab=32000. [arXiv:2411.15242; hf]

Sub-quadratic (SSM backbone): runs the long_500k cell; the shared-attention
caches are sequence-sharded."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    rope_theta=1e4,
    ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    attn_every=6,
    supports_long=True,
    source="[arXiv:2411.15242; hf]",
)

SMOKE = ArchConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, rope_theta=1e4,
    ssm=SSMConfig(version=2, d_state=8, d_conv=4, expand=2, head_dim=16, chunk=8),
    attn_every=2,
    supports_long=True,
)
