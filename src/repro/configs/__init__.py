"""Architecture config registry: ``--arch <id>`` resolution.

Each module exports CONFIG (the exact published config) and SMOKE (a reduced
same-family variant that runs one forward/train step on CPU)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, ShapeCell, SHAPES, SHAPES_BY_NAME, cells_for

from repro.configs import (
    qwen2_vl_7b, granite_20b, phi4_mini_3_8b, deepseek_coder_33b, qwen2_7b,
    mixtral_8x7b, grok_1_314b, falcon_mamba_7b, zamba2_2_7b, whisper_medium,
)

_MODULES = {
    "qwen2-vl-7b": qwen2_vl_7b,
    "granite-20b": granite_20b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "qwen2-7b": qwen2_7b,
    "mixtral-8x7b": mixtral_8x7b,
    "grok-1-314b": grok_1_314b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "zamba2-2.7b": zamba2_2_7b,
    "whisper-medium": whisper_medium,
}

ARCH_IDS = tuple(_MODULES)
CONFIGS = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKES = {k: m.SMOKE for k, m in _MODULES.items()}


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    table = SMOKES if smoke else CONFIGS
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(table)}")
    return table[arch_id]
