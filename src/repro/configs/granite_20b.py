"""granite-20b — dense code model, llama-arch with MQA: 52L d_model=6144
48H (GQA kv=1) d_ff=24576 vocab=49152. [arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152,
    rope_theta=1e4,
    supports_long=False, long_skip_reason="full attention, quadratic in seq",
    source="[arXiv:2405.04324; hf]",
)

SMOKE = ArchConfig(
    name="granite-20b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=256, rope_theta=1e4,
    supports_long=False,
)
