"""Train-step builder: microbatched gradient accumulation + clipping + update.

One function (``make_train_step``) serves the trainer, the smoke tests and the
multi-pod dry-run. Distribution is entirely declarative: the caller jits the
returned function with sharded in/out specs; GSPMD inserts the per-layer FSDP
all-gathers, TP collectives and gradient reduce-scatters.

Distributed-optimization knobs:

* ``microbatches`` — grad accumulation via ``lax.scan`` bounds activation
  memory to one microbatch.
* ``grad_dtype`` — "float32" (default) or "bfloat16". bf16 halves both the
  accumulator memory and, because XLA reduces in the tensor dtype, the bytes
  of every gradient reduce-scatter (the §Perf collective-term lever). The
  fp32 Adam moments act as the error-feedback accumulator.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import clip_by_global_norm

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(
    model,
    optimizer,
    *,
    microbatches: int = 1,
    grad_dtype: str = "float32",
    clip_norm: float = 1.0,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(params, opt_state, batch):
        G = microbatches
        if G > 1:
            def split(x):
                return x.reshape((G, x.shape[0] // G) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def micro(carry, mb):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gsum, grads)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: (g.astype(jnp.float32) / G).astype(grad_dtype), gsum)
            loss = lsum / G
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "step": new_opt["step"],
        }
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        return {"loss": model.loss(params, batch)}
    return eval_step
