"""Training driver: jitted sharded train_step + data prefetch + fault-tolerant
checkpointing through the zLLM store.

Fault-tolerance contract (exercised by tests and examples):

* checkpoints commit atomically (tmp+fsync+rename, manifest with hash),
* ``resume=True`` restarts from the latest manifest entry — a killed run
  (``FailureInjector``) loses at most ``ckpt_every`` steps,
* restore is elastic: a checkpoint from any mesh restores onto the current
  mesh via ``device_put`` with this run's shardings,
* checkpoint writes are async (write-behind) and go through zLLM, so a run's
  storage footprint is FileDedup+TensorDedup+BitX-compressed against its
  first checkpoint.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticTokens
from repro.models.api import (abstract_params, get_model, input_templates,
                              param_shardings)
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.sharding.rules import ShardingRules, spec_tree_shardings
from repro.train.step import make_train_step

__all__ = ["TrainConfig", "Trainer", "SimulatedFailure", "FailureInjector"]


class SimulatedFailure(RuntimeError):
    """Raised by FailureInjector to emulate a node crash mid-run."""


@dataclass
class FailureInjector:
    fail_at_step: Optional[int] = None

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class TrainConfig:
    arch: ArchConfig
    seq_len: int = 128
    global_batch: int = 8
    microbatches: int = 1
    steps: int = 20
    ckpt_every: int = 10
    run_dir: str = "/tmp/repro-run"
    resume: bool = True
    grad_dtype: str = "float32"
    remat_policy: str = "nothing"
    optimizer: Optional[OptimizerConfig] = None
    mesh_shape: Optional[tuple] = None     # (data, model); None -> all devices on data
    seed: int = 0
    async_checkpoint: bool = True
    keep_plain_ckpt: bool = True


class Trainer:
    def __init__(self, cfg: TrainConfig, store=None, run_id: str = "run",
                 failure: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.failure = failure or FailureInjector()
        from repro.launch.mesh import make_local_mesh
        nd = len(jax.devices())
        data, model = cfg.mesh_shape or (nd, 1)
        self.mesh = make_local_mesh(data, model)
        self.rules = ShardingRules.for_mesh(self.mesh)
        self.model = get_model(cfg.arch, self.mesh, self.rules, cfg.remat_policy)
        ocfg = cfg.optimizer or OptimizerConfig(name=cfg.arch.optimizer,
                                                total_steps=cfg.steps)
        self.optimizer = make_optimizer(ocfg)
        self.ckpt = CheckpointManager(cfg.run_dir, store=store, run_id=run_id,
                                      keep_plain=cfg.keep_plain_ckpt)

        self.p_sh = param_shardings(cfg.arch, self.mesh, self.rules)
        self.o_sh = spec_tree_shardings(
            self.optimizer.state_templates(self.model.param_templates()),
            self.mesh, self.rules)
        step_fn = make_train_step(self.model, self.optimizer,
                                  microbatches=cfg.microbatches,
                                  grad_dtype=cfg.grad_dtype)
        self._step = jax.jit(step_fn, in_shardings=(self.p_sh, self.o_sh, None),
                             out_shardings=(self.p_sh, self.o_sh, None),
                             donate_argnums=(0, 1))
        self.data = SyntheticTokens(DataConfig(
            vocab=cfg.arch.vocab, seq_len=cfg.seq_len, global_batch=cfg.global_batch,
            seed=cfg.seed))
        self.history: List[Dict[str, float]] = []
        self.start_step = 0
        self._init_state()

    # ------------------------------------------------------------------
    def _init_state(self):
        cfg = self.cfg
        restored = None
        if cfg.resume:
            opt_tmpl = self.optimizer.init(
                {k: np.zeros(s.shape, s.dtype) for k, s in abstract_params(cfg.arch).items()})
            step, params, opt = self.ckpt.restore_sharded(
                self.mesh, self.p_sh, opt_template=opt_tmpl, opt_shardings=self.o_sh)
            if step is not None:
                self.params, self.opt_state, self.start_step = params, opt, step
                restored = step
        if restored is None:
            from repro.models.api import init_params
            key = jax.random.PRNGKey(cfg.seed)
            params = init_params(cfg.arch, key)
            self.params = {k: jax.device_put(v, self.p_sh[k]) for k, v in params.items()}
            self.opt_state = jax.tree.map(
                lambda x, s: jax.device_put(x, s),
                self.optimizer.init(self.params), self.o_sh)
        self.resumed_from = restored

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
        cfg = self.cfg
        end = steps if steps is not None else cfg.steps
        self.data.step = self.start_step
        it = PrefetchIterator(iter(self.data), prefetch=2)
        try:
            for step in range(self.start_step, end):
                batch = next(it)
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self._step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                rec = {"step": step + 1, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "sec": time.perf_counter() - t0}
                self.history.append(rec)
                if (step + 1) % cfg.ckpt_every == 0 or step + 1 == end:
                    if cfg.async_checkpoint:
                        self.ckpt.save_async(step + 1, self.params, self.opt_state)
                    else:
                        self.ckpt.save(step + 1, self.params, self.opt_state)
                self.failure.check(step + 1)
        finally:
            it.close()
            self.ckpt.wait()
        return self.history
