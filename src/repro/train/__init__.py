"""train subsystem."""
