"""Serving engine: batched prefill + decode over the model API, with
cold-start loading straight from the compressed zLLM store (paper §4.4.4).

The decode loop jits one ``decode_step`` (cache donated, so the KV cache is
updated in place on device) and greedily samples. ``RequestBatcher`` groups
pending requests into fixed-size batches — static batching; the per-request
bookkeeping (prompt lengths, stop conditions) lives host-side.

``ServeEngine.from_store`` is the paper's model-serving cold start: retrieve
the checkpoint from the zLLM store (BitX-decode against its base), verify the
content hash, and device_put with this mesh's shardings.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.api import get_model, param_shardings
from repro.sharding.rules import ShardingRules

__all__ = ["ServeEngine", "RequestBatcher", "GenerateResult"]


@dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, prompt+new)
    prompt_len: int
    n_new: int


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Dict[str, jax.Array],
                 mesh=None, rules: Optional[ShardingRules] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.model = get_model(cfg, mesh, rules)
        self.params = params
        self._prefill = jax.jit(lambda p, b: self.model.prefill(p, b))
        self._decode = jax.jit(lambda p, b, c: self.model.decode_step(p, b, c),
                               donate_argnums=(2,))
        # which cache entries grow along a sequence axis (axes tagged "sp")
        tmpl = self.model.cache_templates(1, 8)
        self._grow_axes = {k: v.axes.index("sp") for k, v in tmpl.items()
                           if "sp" in v.axes}

    # ------------------------------------------------------------------
    @classmethod
    def from_store(cls, store, repo_id: str, filename: str, cfg: ArchConfig,
                   mesh=None, rules: Optional[ShardingRules] = None,
                   param_prefix: str = "params/") -> "ServeEngine":
        """Cold start from the compressed store: BitX-decode, verify, shard.

        The decode fan-out inside ``retrieve_file`` runs on the store's
        configured ``ArrayBackend`` — with ``backend="jax"`` the byte-plane
        merges of the whole checkpoint execute as dtype-bucketed fused
        kernel launches instead of per-tensor numpy loops, so the cold-start
        decode rides the same accelerator the params are about to land on.
        The reconstructed bytes are backend-independent (bit-identity is
        test-enforced), so the spool file below is too.
        """
        import io
        import os
        import tempfile
        import ml_dtypes
        from repro.formats import safetensors as st

        data = store.retrieve_file(repo_id, filename, verify=True)
        # spool to a private temp file (mkstemp, not a guessable name) so the
        # safetensors mmap loader can do its zero-copy thing, and always
        # unlink it — a whole checkpoint must not leak into /tmp per cold
        # start (load_file materializes the arrays before we return)
        fd, tmp = tempfile.mkstemp(prefix="serve-", suffix=".safetensors")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            flat = st.load_file(tmp)
            infos, _, _ = st.read_header(tmp)
        finally:
            os.unlink(tmp)
        tags = {ti.name: ti.dtype_str for ti in infos}
        params = {}
        for k, v in flat.items():
            if not k.startswith(param_prefix):
                continue
            name = k[len(param_prefix):]
            if tags.get(k) == "BF16":
                v = v.view(ml_dtypes.bfloat16)
            params[name] = v
        if mesh is not None and rules is not None:
            sh = param_shardings(cfg, mesh, rules)
            params = {k: jax.device_put(v, sh[k]) for k, v in params.items()}
        else:
            params = {k: jnp.asarray(v) for k, v in params.items()}
        return cls(cfg, params, mesh, rules)

    # ------------------------------------------------------------------
    def _pad_cache(self, cache: Dict, extra: int) -> Dict:
        """Extend growing cache arrays by ``extra`` positions."""
        out = dict(cache)
        for k, ax in self._grow_axes.items():
            arr = cache[k]
            pad = [(0, 0)] * arr.ndim
            pad[ax] = (0, extra)
            out[k] = jnp.pad(arr, pad)
        return out

    def generate(self, prompts: np.ndarray, n_new: int,
                 extra_inputs: Optional[Dict] = None) -> GenerateResult:
        """Greedy generation. prompts: (B, S) int32."""
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, n_new)
        toks = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, {"tokens": toks[-1][:, None]}, cache)
            toks.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        new = np.stack([np.asarray(t) for t in toks], axis=1)
        return GenerateResult(np.concatenate([prompts, new], axis=1), S, n_new)


class RequestBatcher:
    """Static batcher: groups queued prompts into fixed-size generation calls."""

    def __init__(self, engine: ServeEngine, batch_size: int, n_new: int,
                 pad_id: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.n_new = n_new
        self.pad_id = pad_id
        self._q: "queue.Queue[Tuple[int, np.ndarray]]" = queue.Queue()
        self._results: Dict[int, np.ndarray] = {}
        self._next_id = 0

    def submit(self, prompt: Sequence[int]) -> int:
        rid = self._next_id
        self._next_id += 1
        self._q.put((rid, np.asarray(prompt, np.int32)))
        return rid

    def run_once(self) -> List[int]:
        """Serve one batch; returns completed request ids."""
        batch: List[Tuple[int, np.ndarray]] = []
        while len(batch) < self.batch_size and not self._q.empty():
            batch.append(self._q.get())
        if not batch:
            return []
        maxlen = max(len(p) for _, p in batch)
        rows = np.full((self.batch_size, maxlen), self.pad_id, np.int32)
        for i, (_, p) in enumerate(batch):
            rows[i, maxlen - len(p):] = p      # left-pad
        res = self.engine.generate(rows, self.n_new)
        done = []
        for i, (rid, _) in enumerate(batch):
            self._results[rid] = res.tokens[i, maxlen:]
            done.append(rid)
        return done

    def result(self, rid: int) -> Optional[np.ndarray]:
        return self._results.get(rid)
