"""serve subsystem."""
