"""serve subsystem.

Two servers live here, matching ZipLLM's two serving surfaces:

* ``repro.serve.store_server`` — the async *storage* server: concurrent
  bit-exact file/tensor retrieval over the mmap'd zLLM store (stdlib
  asyncio; no jax dependency).
* ``repro.serve.engine`` — the *model* serving engine: batched
  prefill/decode with cold-start loading straight from the compressed
  store (imports jax; do not import it from storage-only contexts).

Submodules are intentionally not re-exported here so importing the storage
server never drags in the jax stack.
"""
