"""Remote peer client: a ``ZLLMStore``-shaped handle over the wire.

The replicated tier (``repro.serve.router.StoreRouter``) converges replica
groups through a narrow set of store primitives — enqueue a spooled ingest,
diff per-key index state, ship container bytes verbatim, adopt them
sha256-verified, union tombstones, restore quarantined versions. PR 6
proved those primitives inside one process; this module promotes them to a
peer-to-peer HTTP protocol so a replica group can span real server
processes. :class:`PeerStore` implements the **RootHandle** subset of the
``ZLLMStore`` API the router actually calls (same method names, same
signatures, same exception contracts), so ``StoreRouter`` holds a mix of
local roots and remote peers behind one interface and the replication
logic stays polymorphic:

==========================  =============================================
local root (``ZLLMStore``)  remote peer (``PeerStore``)
==========================  =============================================
``file_index`` dict         cached snapshot of ``GET /peer/index_digest``
``lifecycle`` graph         :class:`_PeerLifecycle` view over the snapshot
``container_digest``        ``GET /peer/container/<key@gN>?digest=1``
``adopt_container``         resumable upload via ``POST /peer/adopt``
``adopt_index_record``      ``POST /peer/adopt?kind=record``
``apply_tombstone``         ``POST /peer/tombstones``
``restore_version``         upload via ``POST /peer/adopt?kind=restore``
``enqueue_ingest``          ``PUT /repo/<id>/file/<name>`` (spool upload)
``spool_dir()``             a *local* staging directory for ship buffers
==========================  =============================================

Transfers are **authenticated by digest**: every container body carries
its sha256 (query param on upload, ``x-zllm-sha256`` header on download)
and the receiving side refuses bytes that do not hash to it — the same
end-to-end identity check in-process adoption performs, now guarding the
wire too. Shipping is **resumable**: downloads stage into a ``.part``
file and continue with ``Range: bytes=`` after a killed transfer; uploads
carry an ``x-zllm-offset`` and re-sync against the server's partial
``.part`` (a ``409`` answers the current offset). ``.part`` staging files
are crash debris by construction — ``fsck(repair=True)`` sweeps them.

Failure policy: control-plane reads (``file_index``, ``lifecycle``) never
raise — an unreachable peer serves its last-known snapshot (empty when
none), so routing and diffing survive partitions; explicit refreshes and
every mutation raise :class:`PeerUnreachable`, which the router's health
tracker turns into suspect-backoff state exactly as for a local error.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import socket
import tempfile
import threading
import time
from http.client import HTTPConnection, HTTPException
from typing import Dict, List, Optional, Sequence, Tuple
from urllib.parse import quote, urlencode, urlsplit

from repro.core.bitx import TMP_SUFFIX
from repro.core.lifecycle import make_vid

__all__ = ["PeerStore", "PeerUnreachable"]

_CHUNK = 1 << 20


class PeerUnreachable(ConnectionError):
    """The peer did not answer (refused, timed out, died mid-transfer)."""


class _PeerVersion:
    """Snapshot view of one container version on the peer (no local
    ``path`` — bytes are fetched on demand)."""

    __slots__ = ("key", "gen", "nbytes", "quarantined")

    def __init__(self, key: str, gen: int, nbytes: int, quarantined: bool):
        self.key, self.gen = key, int(gen)
        self.nbytes, self.quarantined = int(nbytes), bool(quarantined)

    @property
    def vid(self) -> str:
        return make_vid(self.key, self.gen)


class _PeerLifecycle:
    """Read-only ``ContainerLifecycle`` facade over the peer snapshot —
    exactly the attributes the router's anti-entropy logic touches."""

    def __init__(self, peer: "PeerStore"):
        self._peer = peer

    @property
    def tombstones(self) -> Dict[str, Tuple[int, float]]:
        snap = self._peer._snapshot()
        return {k: (int(g), float(ts))
                for k, (g, ts) in snap.get("tombstones", {}).items()}

    def tombstone_for(self, key: str) -> Optional[Tuple[int, float]]:
        return self.tombstones.get(key)

    @property
    def versions(self) -> Dict[str, _PeerVersion]:
        snap = self._peer._snapshot()
        out = {}
        for vid, v in snap.get("versions", {}).items():
            key, _, gen = vid.rpartition("@g")
            out[vid] = _PeerVersion(key, int(gen), v.get("nbytes", 0),
                                    v.get("quarantined", False))
        return out

    @property
    def edges(self) -> Dict[str, List[str]]:
        snap = self._peer._snapshot()
        return {vid: list(v.get("edges", ()))
                for vid, v in snap.get("versions", {}).items()}

    def get(self, key: str, gen: int) -> Optional[_PeerVersion]:
        return self.versions.get(make_vid(key, gen))

    def exists(self, key: str, gen: int) -> bool:
        return self.get(key, gen) is not None


class _PeerFsck:
    """Shape-compatible stand-in for ``FsckReport`` built from the peer's
    ``/admin/fsck`` JSON."""

    def __init__(self, d: Dict):
        self._d = d
        self.ok = bool(d.get("ok", False))
        self.orphans = [None] * int(d.get("orphans", 0))
        self.quarantined = [None] * int(d.get("quarantined", 0))

    def summary(self) -> Dict:
        return self._d


class PeerStore:
    """HTTP client for one remote peer, presenting the RootHandle subset
    of the ``ZLLMStore`` API (see module docstring). Thread-safe: one
    connection per request, a lock only around the snapshot cache."""

    is_peer = True

    def __init__(self, url: str, *, timeout: float = 10.0,
                 snapshot_ttl: float = 0.25,
                 staging_dir: Optional[str] = None):
        u = urlsplit(url if "//" in url else "http://" + url)
        self.host, self.port = u.hostname, u.port or 80
        self.url = f"http://{self.host}:{self.port}"
        self.root = self.url  # display parity with ZLLMStore.root
        self.timeout = timeout
        self.snapshot_ttl = snapshot_ttl
        self._staging = staging_dir
        self._staging_owned = staging_dir is None
        self._snap: Optional[Dict] = None
        self._snap_at = -1e9
        self._snap_lock = threading.Lock()
        self.lifecycle = _PeerLifecycle(self)
        # wired by StoreRouter to its own _fault so wire-protocol fault
        # points (peer.ship_mid_body) fire from the coordinator's hook
        self.fault_hook = None

    # -- transport -------------------------------------------------------
    def _request(self, method: str, path: str, body=None,
                 headers: Optional[Dict[str, str]] = None,
                 ) -> Tuple[int, Dict[str, str], bytes]:
        conn = HTTPConnection(self.host, self.port, timeout=self.timeout)
        try:
            conn.request(method, path, body=body, headers=headers or {})
            r = conn.getresponse()
            return r.status, {k.lower(): v for k, v in r.getheaders()}, r.read()
        except (OSError, socket.timeout, HTTPException) as e:
            raise PeerUnreachable(f"{method} {self.url}{path}: "
                                  f"{type(e).__name__}: {e}") from e
        finally:
            conn.close()

    def _json(self, method: str, path: str, body=None,
              headers: Optional[Dict[str, str]] = None,
              ok: Tuple[int, ...] = (200,)) -> Dict:
        status, _, payload = self._request(method, path, body, headers)
        if status not in ok:
            raise RuntimeError(f"{method} {path} on {self.url} answered "
                               f"{status}: {payload[:200]!r}")
        return json.loads(payload or b"{}")

    def probe(self) -> bool:
        """Health probe: does the peer answer ``/healthz`` right now?"""
        try:
            return bool(self._json("GET", "/healthz").get("ok"))
        except (PeerUnreachable, RuntimeError, ValueError):
            return False

    # -- snapshot (the wire form of file_index + lifecycle) --------------
    def _snapshot(self) -> Dict:
        with self._snap_lock:
            fresh = (self._snap is not None
                     and time.monotonic() - self._snap_at < self.snapshot_ttl)
            if fresh:
                return self._snap
        try:
            return self.refresh_snapshot()
        except (PeerUnreachable, RuntimeError, ValueError):
            with self._snap_lock:  # stale beats crashed for routing reads
                return self._snap if self._snap is not None else {}

    def refresh_snapshot(self) -> Dict:
        """Fetch ``/peer/index_digest`` now; raises when unreachable —
        anti-entropy calls this to guarantee it diffs live state, while
        plain routing reads tolerate a stale snapshot."""
        snap = self._json("GET", "/peer/index_digest")
        with self._snap_lock:
            self._snap, self._snap_at = snap, time.monotonic()
        return snap

    def invalidate(self) -> None:
        with self._snap_lock:
            self._snap_at = -1e9

    @property
    def file_index(self) -> Dict[str, Dict]:
        return self._snapshot().get("keys", {})

    @property
    def base_paths(self) -> Dict[str, str]:
        return {b: "" for b in self._snapshot().get("base_paths", ())}

    @property
    def read_gen(self) -> int:
        return int(self._snapshot().get("read_gen", -1))

    # -- replication primitives over the wire ----------------------------
    def container_digest(self, key: str, gen: int,
                         allow_quarantined: bool = False) -> str:
        vid = quote(make_vid(key, gen), safe="")
        q = "?digest=1" + ("&allow_quarantined=1" if allow_quarantined else "")
        status, _, payload = self._request(
            "GET", f"/peer/container/{vid}{q}")
        if status == 404:
            raise KeyError(f"container version {make_vid(key, gen)} is "
                           f"unknown on {self.url}")
        if status == 410:
            raise RuntimeError(f"container version {make_vid(key, gen)} is "
                               f"quarantined on {self.url}")
        if status != 200:
            raise RuntimeError(f"digest of {make_vid(key, gen)} on "
                               f"{self.url}: {status} {payload[:200]!r}")
        return json.loads(payload)["sha256"]

    def fetch_container(self, key: str, gen: int, dst_dir: str) -> str:
        """Download one container's verbatim bytes into ``dst_dir``,
        resumably: bytes stage into a ``.part`` sibling, a retry continues
        with ``Range: bytes=<have>-`` from wherever the last attempt died,
        and the finished file is sha256-verified against the peer's
        ``x-zllm-sha256`` before the atomic rename."""
        vid = make_vid(key, gen)
        final = os.path.join(dst_dir, "fetch-" + vid.replace("/", "__"))
        part = final + TMP_SUFFIX
        have = os.path.getsize(part) if os.path.exists(part) else 0
        headers = {"range": f"bytes={have}-"} if have else {}
        qpath = "/peer/container/" + quote(vid, safe="")
        conn = HTTPConnection(self.host, self.port, timeout=self.timeout)
        try:
            conn.request("GET", qpath, headers=headers)
            r = conn.getresponse()
            hdrs = {k.lower(): v for k, v in r.getheaders()}
            if r.status == 404:
                r.read()
                raise KeyError(f"container version {vid} unknown on {self.url}")
            if r.status == 410:
                r.read()
                raise RuntimeError(f"container version {vid} quarantined on "
                                   f"{self.url}")
            if r.status == 416:  # .part already holds the full body
                r.read()
            elif r.status in (200, 206):
                mode = "ab" if r.status == 206 else "wb"
                with open(part, mode) as f:
                    while True:
                        chunk = r.read(_CHUNK)
                        if not chunk:
                            break
                        f.write(chunk)
                    f.flush()
                    os.fsync(f.fileno())
            else:
                body = r.read()
                raise RuntimeError(f"fetch {vid} from {self.url}: "
                                   f"{r.status} {body[:200]!r}")
        except (OSError, socket.timeout, HTTPException) as e:
            # partial bytes stay in .part — the next attempt resumes
            raise PeerUnreachable(f"fetch {vid} from {self.url} died "
                                  f"mid-transfer: {e}") from e
        finally:
            conn.close()
        expect = hdrs.get("x-zllm-sha256", "")
        h = hashlib.sha256()
        with open(part, "rb") as f:
            for chunk in iter(lambda: f.read(_CHUNK), b""):
                h.update(chunk)
        if expect and h.hexdigest() != expect:
            os.remove(part)  # corrupt partial state: restart from zero
            raise ValueError(f"fetched container {vid} failed sha256 "
                             f"verification against {self.url}")
        os.replace(part, final)
        return final

    def adopt_container(self, key: str, gen: int, src_path: str,
                        expected_sha256: Optional[str] = None) -> bool:
        """Ship ``src_path``'s bytes to the peer and have it adopt them as
        ``key@gN`` (idempotent, sha256-verified server-side). Resumable:
        a killed upload re-syncs against the peer's ``.part`` offset."""
        if expected_sha256 is None:
            h = hashlib.sha256()
            with open(src_path, "rb") as f:
                for chunk in iter(lambda: f.read(_CHUNK), b""):
                    h.update(chunk)
            expected_sha256 = h.hexdigest()
        total = os.path.getsize(src_path)
        q = urlencode({"key": key, "gen": gen, "sha256": expected_sha256,
                       "total": total})
        offset, last = 0, None
        for _ in range(4):
            body = _UploadReader(src_path, offset, self.fault_hook)
            try:
                status, _, payload = self._request(
                    "POST", f"/peer/adopt?{q}", body=body,
                    headers={"content-length": str(total - offset),
                             "x-zllm-offset": str(offset)})
            except PeerUnreachable as e:
                last = e
                offset = self._adopt_offset(q)
                if offset is None:  # peer adopted before the answer died
                    self.invalidate()
                    return True
                continue
            finally:
                body.close()
            if status == 409:  # offset mismatch: re-sync and resend
                offset = int(json.loads(payload).get("offset", 0))
                continue
            if status != 200:
                raise RuntimeError(f"adopt {make_vid(key, gen)} on "
                                   f"{self.url}: {status} {payload[:200]!r}")
            self.invalidate()
            return bool(json.loads(payload).get("adopted"))
        raise last or PeerUnreachable(
            f"adopt {make_vid(key, gen)} on {self.url}: retries exhausted")

    def _adopt_offset(self, q: str) -> Optional[int]:
        """Re-sync a killed upload: ask the peer how much of the ``.part``
        it holds (``None`` == it already adopted the full container)."""
        info = self._json("POST", f"/peer/adopt?{q}&stat=1",
                          headers={"content-length": "0"})
        return None if info.get("adopted") else int(info.get("offset", 0))

    def adopt_index_record(self, key: str, rec: Dict) -> None:
        rec = {k: v for k, v in rec.items() if k != "path"}
        status, _, payload = self._request(
            "POST", "/peer/adopt?kind=record",
            body=json.dumps({"key": key, "rec": rec}).encode(),
            headers={"content-type": "application/json"})
        if status == 409:  # ref closure not live yet — mirror the local
            raise KeyError(json.loads(payload).get("error", "ref not live"))
        if status != 200:
            raise RuntimeError(f"adopt record {key} on {self.url}: "
                               f"{status} {payload[:200]!r}")
        self.invalidate()

    def apply_tombstone(self, key: str, gen: int, ts: float) -> bool:
        out = self._json("POST", "/peer/tombstones",
                         body=json.dumps(
                             {"tombstones": [[key, int(gen), float(ts)]]}
                         ).encode(),
                         headers={"content-type": "application/json"})
        self.invalidate()
        return bool(out.get("applied", 0))

    def restore_version(self, key: str, gen: int, staged_path: str,
                        expected_sha256: Optional[str] = None) -> bool:
        """Quarantine-restore on the peer: upload the healthy donor bytes
        (already staged locally) and have the peer swap them back in."""
        if expected_sha256 is None:
            h = hashlib.sha256()
            with open(staged_path, "rb") as f:
                for chunk in iter(lambda: f.read(_CHUNK), b""):
                    h.update(chunk)
            expected_sha256 = h.hexdigest()
        total = os.path.getsize(staged_path)
        q = urlencode({"key": key, "gen": gen, "sha256": expected_sha256,
                       "total": total, "kind": "restore"})
        with open(staged_path, "rb") as body:
            out = self._json("POST", f"/peer/adopt?{q}", body=body,
                             headers={"content-length": str(total),
                                      "x-zllm-offset": "0"})
        try:
            os.remove(staged_path)  # uploaded: the local stage is debris
        except OSError:
            pass
        self.invalidate()
        return bool(out.get("restored"))

    # -- write/read plumbing the router fans out through ------------------
    def spool_dir(self) -> str:
        """LOCAL staging directory for bytes headed to this peer (fan-out
        copies, ship buffers). The peer's own spool is its server's."""
        if self._staging is None:
            self._staging = tempfile.mkdtemp(prefix="zllm-peer-")
        os.makedirs(self._staging, exist_ok=True)
        return self._staging

    def enqueue_ingest(self, uploads: Sequence, *, cleanup: bool = False) -> str:
        """Upload the spooled file(s) to the peer's PUT route (its server
        spools + enqueues exactly as a local ``enqueue_ingest`` would) and
        return the LAST job id — the router fans out one file at a time."""
        jid = None
        for u in uploads:
            path, repo_id, filename, base = (tuple(u) + (None, None))[:4]
            filename = filename or os.path.basename(path)
            target = (f"/repo/{quote(repo_id, safe='/')}/file/"
                      f"{quote(filename, safe='')}")
            if base:
                target += "?" + urlencode({"base": base})
            total = os.path.getsize(path)
            with open(path, "rb") as body:
                out = self._json("PUT", target, body=body,
                                 headers={"content-length": str(total)},
                                 ok=(200, 202))
            jid = out.get("job_id") or (out.get("job") or {}).get("job_id")
            if cleanup:
                try:
                    os.remove(path)
                except OSError:
                    pass
        self.invalidate()
        return jid

    def ingest_job(self, job_id: str) -> Optional[Dict]:
        try:
            status, _, payload = self._request(
                "GET", f"/admin/jobs?{urlencode({'job': job_id})}")
        except PeerUnreachable:
            return None  # await_quorum counts an unreachable job as dead
        if status != 200:
            return None
        return json.loads(payload)

    def ingest_jobs(self, limit: int = 64) -> List[Dict]:
        try:
            return self._json("GET", "/admin/jobs").get("jobs", [])[:limit]
        except (PeerUnreachable, RuntimeError, ValueError):
            return []

    def delete_file(self, repo_id: str, filename: str) -> bool:
        out = self._json("DELETE",
                         f"/repo/{quote(repo_id, safe='/')}/file/"
                         f"{quote(filename, safe='')}")
        self.invalidate()
        return bool(out.get("deleted", 0))

    def delete_repo(self, repo_id: str) -> int:
        out = self._json("DELETE", f"/repo/{quote(repo_id, safe='/')}")
        self.invalidate()
        return int(out.get("deleted", 0))

    def retrieve_file(self, repo_id: str,
                      filename: str = "model.safetensors") -> bytes:
        status, _, payload = self._request(
            "GET", f"/repo/{quote(repo_id, safe='/')}/file/"
                   f"{quote(filename, safe='')}")
        if status == 404:
            raise KeyError(f"{repo_id}/{filename} unknown on {self.url}")
        if status != 200:
            raise RuntimeError(f"retrieve {repo_id}/{filename} from "
                               f"{self.url}: {status}")
        return payload

    # -- admin parity -----------------------------------------------------
    def save_index(self) -> None:
        """No-op: the peer's server persists its own index after every
        adopt / tombstone / delete it serves."""

    def fsck(self, repair: bool = False,
             spot_check: Optional[int] = 4) -> _PeerFsck:
        q = urlencode({"repair": int(repair),
                       "spot_check": ("none" if spot_check is None
                                      else spot_check)})
        return _PeerFsck(self._json("POST", f"/admin/fsck?{q}",
                                    headers={"content-length": "0"}))

    def summary(self) -> Dict:
        try:
            out = self._json("GET", "/stats")["store"]
            out.setdefault("unreachable", False)
            return out
        except (PeerUnreachable, RuntimeError, ValueError, KeyError):
            zeros = {k: 0 for k in ("n_files", "raw_bytes", "stored_bytes",
                                    "file_dedup_hits", "near_dup_hits")}
            zeros["lifecycle"] = {k: 0 for k in (
                "versions", "live_bytes", "superseded_bytes",
                "reclaimed_bytes", "collected", "gc_runs", "deleted_files",
                "compact_runs", "compaction_reclaimed_bytes",
                "gc_max_pause_ms")}
            zeros.update(read_gen=-1, reduction_ratio=0.0, unreachable=True,
                         peer=self.url)
            return zeros

    def close(self) -> None:
        if self._staging_owned and self._staging is not None:
            shutil.rmtree(self._staging, ignore_errors=True)
            self._staging = None


class _UploadReader:
    """File-like upload body starting at ``offset``. ``http.client``
    drains it in blocks, so a ``fault_hook`` (the router's crash harness)
    fires **mid-body** — on the second read, after the first block hit the
    wire — simulating a coordinator killed inside a container ship."""

    def __init__(self, path: str, offset: int, fault_hook=None):
        self._f = open(path, "rb")
        self._f.seek(offset)
        self._fault_hook = fault_hook
        self._reads = 0

    def read(self, n: int = -1) -> bytes:
        self._reads += 1
        if self._reads == 2 and self._fault_hook is not None:
            self._fault_hook("peer.ship_mid_body")
        return self._f.read(n)

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass
