"""Async retrieval engine + HTTP front for the zLLM store (stdlib-only).

ZipLLM's target deployment is hub-scale: tens of PB of model weights served
to millions of users. ``ZLLMStore`` provides the storage-side concurrency
substrate (mmap readers with pin counts, a read gate with read generations,
publish epochs — see ``repro.core.pipeline``); this module turns it into a
serving system:

* :class:`RetrievalEngine` — asyncio facade. Decodes run on a bounded
  thread pool (sha256/zstd/XOR release the GIL, so concurrent retrievals
  genuinely overlap); concurrent requests for the same object are
  *single-flighted* (one decode, N waiters — ``repro.serve.singleflight``);
  finished responses land in a byte-budgeted LRU. Every flight and cache
  entry is keyed by the store's ``read_gen``, so an ingest / delete / gc
  rolls the caches over atomically: a request issued after a mutation can
  never be served a pre-mutation decode (snapshot isolation, with the
  store's read gate guaranteeing the decode itself never races physical
  reclamation).

* :class:`StoreServer` — a minimal HTTP/1.1 front over asyncio streams
  (deliberately dependency-free; this is the paper-repro analogue of the
  production gateway, not a gateway itself):

  ========================================  =====================================
  ``GET /healthz``                          liveness + read_gen
  ``GET /stats``                            engine + store counters (JSON)
  ``GET /repo/<repo_id>/file/<filename>``   the bit-exact safetensors file
  ``GET /repo/<repo_id>/tensor/<name>``     one tensor's raw little-endian bytes
  ``[?file=<filename>]``                    (default file: model.safetensors)
  ``GET|POST /admin/compact``               dedup-aware compaction of superseded
                                            generations (returns the report)
  ``GET|POST /admin/gc``                    garbage collection;
  ``[?incremental=1&max_pause_ms=50]``      incremental = bounded-pause steps
  ========================================  =====================================

  ``repo_id`` may contain slashes (``org/model``); the ``file``/``tensor``
  path markers disambiguate (file: second-to-last segment; tensor:
  rightmost marker). Tensor names containing a literal ``tensor`` or
  ``file`` segment need the query form
  ``/repo/<repo_id>/tensor?name=<tensor>``. Tensor responses carry
  ``x-tensor-dtype`` / ``x-tensor-shape`` headers; file responses carry
  ``x-content-sha256``. Errors map to 404 (unknown repo/file/tensor), 410
  (quarantined by fsck) and 500 (decode/backend failures).

* :class:`ServerThread` — runs the server on a private event loop in a
  daemon thread, for synchronous harnesses (tests, benches, the soak).

Run standalone::

    PYTHONPATH=src python -m repro.serve.store_server --root /path/to/store
"""

from __future__ import annotations

import argparse
import asyncio
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Tuple
from urllib.parse import parse_qs, unquote, urlsplit

from repro.core.pipeline import ZLLMStore, _LRUCache
from repro.serve.singleflight import SingleFlight

__all__ = ["RetrievalEngine", "StoreServer", "ServerThread", "main"]

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found", 405: "Method Not Allowed",
            410: "Gone", 500: "Internal Server Error"}


class RetrievalEngine:
    """Concurrent retrieval over one :class:`ZLLMStore`.

    Loop-confined: construct and call from a single event loop. The store
    may be mutated concurrently from *other* threads (ingest, delete, gc) —
    that is the supported serving topology; what is not supported is two
    engines fronting one store from two loops with one response cache.
    """

    def __init__(self, store: ZLLMStore, *, max_concurrency: int = 8,
                 cache_bytes: int = 128 << 20, verify: bool = True):
        self.store = store
        self.verify = verify
        self._pool = ThreadPoolExecutor(max_workers=max(1, max_concurrency),
                                        thread_name_prefix="zllm-serve")
        self._flight = SingleFlight()
        # cache_bytes <= 0 disables response caching entirely (the serving
        # bench measures concurrent decodes, not cache hits)
        self._cache = (_LRUCache(max_items=1024, max_bytes=cache_bytes)
                       if cache_bytes > 0 else None)
        self._cache_gen = -1  # read_gen the cached entries belong to
        self.requests = 0
        self.errors = 0

    # -- retrieval ------------------------------------------------------
    async def get_file(self, repo_id: str, filename: str = "model.safetensors") -> bytes:
        """Bit-exact safetensors bytes for ``repo_id/filename``."""
        data, _ = await self.get_file_digest(repo_id, filename)
        return data

    async def get_file_digest(self, repo_id: str,
                              filename: str = "model.safetensors") -> Tuple[bytes, str]:
        """(bytes, sha256 hexdigest). The digest comes from the store's own
        gate-held decode (one hash per flight, on the executor, always
        consistent with the returned bytes) and is cached with the
        response — never recomputed per request on the event loop."""
        return await self._fetch(
            ("file", repo_id, filename),
            lambda: self.store.retrieve_file_digest(repo_id, filename,
                                                    verify=self.verify))

    async def get_tensor(self, repo_id: str, tensor_name: str,
                         filename: str = "model.safetensors") -> Tuple[bytes, Dict]:
        """One tensor's raw bytes + metadata for ``repo_id/filename``."""
        return await self._fetch(
            ("tensor", repo_id, filename, tensor_name),
            lambda: self.store.retrieve_tensor(repo_id, filename, tensor_name,
                                               verify=self.verify))

    async def _fetch(self, key: Tuple, call):
        """Cache → single-flight → executor. The composite key includes the
        store's read_gen: one mutation and every subsequent request misses
        the old view, while an in-flight pre-mutation decode still completes
        under the store's read gate."""
        self.requests += 1
        gen = self.store.read_gen
        ck = (gen,) + key
        if self._cache is not None:
            if gen != self._cache_gen:
                # only current-generation entries are ever servable again —
                # purge instead of letting stale bytes squat on the budget
                self._cache.clear()
                self._cache_gen = gen
            hit = self._cache.get(ck)
            if hit is not None:
                return hit
        loop = asyncio.get_running_loop()

        async def thunk():
            return await loop.run_in_executor(self._pool, call)

        try:
            result = await self._flight.run(ck, thunk)
        except Exception:
            self.errors += 1
            raise
        if self._cache is not None:
            nbytes = len(result[0]) if isinstance(result, tuple) else len(result)
            self._cache.put(ck, result, nbytes)
        return result

    # -- admin ----------------------------------------------------------
    async def run_gc(self, incremental: bool = False,
                     max_pause_ms: float = 50.0) -> Dict[str, int]:
        """Run ``store.gc()`` off-loop. Safe during serving AND during an
        ingest batch on another thread: gc serializes behind the store's
        admin lock, its write gate drains in-flight decodes, and read_gen
        rolls the engine caches over. ``incremental=True`` sweeps in
        bounded steps (target ``max_pause_ms`` exclusive hold each) that
        interleave with the live traffic instead of stopping the world."""
        return await asyncio.get_running_loop().run_in_executor(
            self._pool, lambda: self.store.gc(incremental=incremental,
                                              max_pause_ms=max_pause_ms))

    async def run_compact(self) -> Dict:
        """Run ``store.compact()`` off-loop: rewrite still-referenced
        records out of superseded generations and retire them. The byte
        copying runs concurrently with serving; only the final pointer
        swap holds the read gate (reported as ``exclusive_hold_ms``)."""
        return await asyncio.get_running_loop().run_in_executor(
            self._pool, self.store.compact)

    def stats(self) -> Dict:
        return {
            "requests": self.requests,
            "errors": self.errors,
            "read_gen": self.store.read_gen,
            "singleflight": self._flight.stats(),
            "response_cache": ({"items": len(self._cache),
                                "hits": self._cache.hits,
                                "misses": self._cache.misses}
                               if self._cache is not None else {"disabled": True}),
            "workers": self._pool._max_workers,
            "verify": self.verify,
        }

    async def aclose(self) -> None:
        await asyncio.get_running_loop().run_in_executor(
            None, lambda: self._pool.shutdown(wait=True))


class StoreServer:
    """Minimal asyncio HTTP/1.1 front over a :class:`RetrievalEngine`."""

    def __init__(self, store: ZLLMStore, host: str = "127.0.0.1", port: int = 0,
                 *, max_concurrency: int = 8, cache_bytes: int = 128 << 20,
                 verify: bool = True):
        self.engine = RetrievalEngine(store, max_concurrency=max_concurrency,
                                      cache_bytes=cache_bytes, verify=verify)
        self._host_arg, self._port_arg = host, port
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> Tuple[str, int]:
        self._server = await asyncio.start_server(self._handle, self._host_arg,
                                                  self._port_arg)
        self.host, self.port = self._server.sockets[0].getsockname()[:2]
        return self.host, self.port

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.engine.aclose()

    # -- request handling ------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            request = await asyncio.wait_for(reader.readline(), timeout=30)
            parts = request.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, target = parts[0], parts[1]
            while True:  # drain headers; bodies are not supported (GET only)
                line = await asyncio.wait_for(reader.readline(), timeout=30)
                if line in (b"\r\n", b"\n", b""):
                    break
            # admin routes (mutating) accept POST as well as GET — GET kept
            # for curl/urllib harness convenience; everything else is GET-only
            is_admin = target.split("?", 1)[0].startswith("/admin/")
            if method != "GET" and not (method == "POST" and is_admin):
                await self._respond(writer, 405, {"error": "GET only "
                                                  "(POST allowed on /admin/*)"})
                return
            await self._route(writer, target)
        except (asyncio.TimeoutError, ConnectionError):
            pass
        except ValueError:
            # oversized request/header line (StreamReader limit overrun) —
            # answer 400 instead of leaking an unhandled task exception
            try:
                await self._respond(writer, 400,
                                    {"error": "request line or headers too large"})
            except Exception:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _route(self, writer, target: str) -> None:
        url = urlsplit(target)
        segs = [unquote(s) for s in url.path.split("/") if s]
        qs = parse_qs(url.query)
        try:
            if url.path == "/healthz":
                await self._respond(writer, 200, {"ok": True,
                                                  "read_gen": self.engine.store.read_gen})
            elif url.path == "/admin/compact":
                # dedup-aware compaction: rewrite still-referenced records
                # out of superseded generations, retire the old gens. Runs
                # on the executor; serving continues except for the commit's
                # bounded exclusive hold (returned as exclusive_hold_ms).
                await self._respond(writer, 200, await self.engine.run_compact())
            elif url.path == "/admin/gc":
                inc = qs.get("incremental", ["0"])[0].lower() not in ("0", "false", "")
                pause = float(qs.get("max_pause_ms", ["50"])[0])
                await self._respond(writer, 200,
                                    await self.engine.run_gc(incremental=inc,
                                                             max_pause_ms=pause))
            elif url.path == "/stats":
                # store.summary() walks index/lifecycle dicts — run it on
                # the executor so a slow store never stalls the event loop
                store_stats = await asyncio.get_running_loop().run_in_executor(
                    self.engine._pool, self.engine.store.summary)
                await self._respond(writer, 200, {"server": self.engine.stats(),
                                                  "store": store_stats})
            elif len(segs) >= 4 and segs[0] == "repo" and segs[-2] == "file":
                repo_id = "/".join(segs[1:-2])
                data, sha = await self.engine.get_file_digest(repo_id, segs[-1])
                await self._respond_bytes(writer, data,
                                          [("x-content-sha256", sha)])
            elif (len(segs) >= 3 and segs[0] == "repo" and segs[-1] == "tensor"
                  and "name" in qs):
                # unambiguous form: /repo/<repo_id>/tensor?name=<tensor> —
                # for names where the path grammar below would mis-split
                repo_id = "/".join(segs[1:-1])
                data, meta = await self.engine.get_tensor(
                    repo_id, qs["name"][0],
                    qs.get("file", ["model.safetensors"])[0])
                await self._respond_tensor(writer, data, meta)
            elif len(segs) >= 4 and segs[0] == "repo" and "tensor" in segs[2:-1]:
                # path form: rightmost "tensor" marker splits repo id from
                # tensor name (both may contain slashes; a tensor name with
                # a literal "tensor" segment needs the ?name= form above)
                i = len(segs) - 1 - segs[::-1].index("tensor")
                repo_id = "/".join(segs[1:i])
                tensor_name = "/".join(segs[i + 1:])
                filename = qs.get("file", ["model.safetensors"])[0]
                data, meta = await self.engine.get_tensor(repo_id, tensor_name,
                                                          filename)
                await self._respond_tensor(writer, data, meta)
            else:
                await self._respond(writer, 404, {"error": f"no route for {url.path}"})
        except KeyError as e:
            await self._respond(writer, 404, {"error": str(e)})
        except RuntimeError as e:
            status = 410 if "quarantined" in str(e) else 500
            await self._respond(writer, status, {"error": str(e)})
        except Exception as e:  # backend mismatch, decode failure, ...
            await self._respond(writer, 500,
                                {"error": f"{type(e).__name__}: {e}"})

    async def _respond_tensor(self, writer, data: bytes, meta: Dict) -> None:
        await self._respond_bytes(writer, data, [
            ("x-tensor-dtype", meta["dtype"]),
            ("x-tensor-shape", json.dumps(meta["shape"])),
            ("x-tensor-codec", meta["codec"]),
        ])

    async def _respond(self, writer, status: int, obj: Dict) -> None:
        body = (json.dumps(obj) + "\n").encode()
        await self._write(writer, status, body, "application/json", [])

    async def _respond_bytes(self, writer, data: bytes, extra) -> None:
        await self._write(writer, 200, data, "application/octet-stream",
                          [("x-read-gen", str(self.engine.store.read_gen))] + extra)

    @staticmethod
    async def _write(writer, status: int, body: bytes, ctype: str, extra) -> None:
        head = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
                f"content-type: {ctype}",
                f"content-length: {len(body)}",
                "connection: close"]
        head += [f"{k}: {v}" for k, v in extra]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode())
        writer.write(body)
        await writer.drain()


class ServerThread:
    """Run a :class:`StoreServer` on a private event loop in a daemon
    thread — the harness for synchronous callers (tests, benches, soak).
    Usable as a context manager; ``host``/``port`` are set after start."""

    def __init__(self, store: ZLLMStore, **server_kw):
        self._store = store
        self._kw = server_kw
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self.server: Optional[StoreServer] = None
        self.host: Optional[str] = None
        self.port: Optional[int] = None

    def start(self) -> "ServerThread":
        started = threading.Event()
        fail: list = []

        def run():
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)
            try:
                self.server = StoreServer(self._store, **self._kw)
                host_port = loop.run_until_complete(self.server.start())
            except BaseException as e:  # surface startup failures (e.g.
                # EADDRINUSE) to the caller; self._loop stays None so a
                # defensive stop() returns immediately instead of waiting on
                # a loop that will never run
                fail.append(e)
                self.server = None
                loop.close()
                started.set()
                return
            self._loop = loop
            self.host, self.port = host_port
            started.set()
            try:
                loop.run_forever()
            finally:
                loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="zllm-server")
        self._thread.start()
        started.wait(timeout=60)
        if fail:
            raise fail[0]
        assert self.port is not None, "server failed to start within 60s"
        return self

    def submit(self, coro):
        """Schedule a coroutine on the server loop; returns a concurrent
        Future (e.g. ``submit(engine.run_gc()).result()``)."""
        return asyncio.run_coroutine_threadsafe(coro, self._loop)

    def stop(self) -> None:
        if self._loop is None:
            return
        if self.server is not None:
            asyncio.run_coroutine_threadsafe(self.server.aclose(),
                                             self._loop).result(timeout=60)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=60)
        self._loop = None

    def __enter__(self) -> "ServerThread":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Serve a zLLM store over HTTP (asyncio, stdlib-only)")
    ap.add_argument("--root", required=True, help="store root directory")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8421)
    ap.add_argument("--store-workers", type=int, default=2,
                    help="ZLLMStore decode pool size")
    ap.add_argument("--serve-workers", type=int, default=8,
                    help="concurrent retrieval executor size")
    ap.add_argument("--cache-mb", type=int, default=128)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip sha256 verification of responses")
    args = ap.parse_args(argv)

    store = ZLLMStore(args.root, workers=args.store_workers)
    if not store.load_index():
        print(f"store_server: no index.json under {args.root} "
              f"(serving an empty store)", flush=True)

    async def amain():
        server = StoreServer(store, args.host, args.port,
                             max_concurrency=args.serve_workers,
                             cache_bytes=args.cache_mb << 20,
                             verify=not args.no_verify)
        host, port = await server.start()
        print(f"store_server: serving {args.root} on http://{host}:{port}",
              flush=True)
        await server.serve_forever()

    try:
        asyncio.run(amain())
    except KeyboardInterrupt:
        pass
    finally:
        store.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
