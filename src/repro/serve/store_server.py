"""Async serving engine + HTTP/1.1 front for the zLLM store (stdlib-only).

ZipLLM's target deployment is hub-scale: tens of PB of model weights served
to millions of users. ``ZLLMStore`` provides the storage-side concurrency
substrate (mmap readers with pin counts, a read gate with read generations,
publish epochs, a spooled-ingest job queue — see ``repro.core.pipeline``);
this module turns it into a servable hub node:

* :class:`RetrievalEngine` — asyncio facade over ONE store. Decodes run on
  a bounded thread pool (sha256/zstd/XOR release the GIL, so concurrent
  retrievals genuinely overlap); concurrent requests for the same object
  are *single-flighted* (one decode, N waiters —
  ``repro.serve.singleflight``); finished responses land in a two-tier
  decoded cache (byte-budgeted RAM LRU over a disk spill directory under
  the store root — ``TieredResponseCache``), keyed by each object's
  strong entity tag. Flights are additionally keyed by the store's
  ``read_gen`` (snapshot isolation), and entries of re-registered /
  deleted keys are purged when a generation change is observed — the
  store's read gate guarantees the decode itself never races physical
  reclamation.

* :class:`StoreServer` — an HTTP/1.1 front over asyncio streams
  (deliberately dependency-free; the paper-repro analogue of the
  production gateway). One server fronts one store *or* a
  :class:`repro.serve.router.StoreRouter` over N roots (consistent-hash
  repo placement, per-root stats, admin fan-out) — every deployment is
  wrapped in a router internally so both topologies share one code path.

  The protocol surface (the canonical registry is :data:`ROUTES`;
  ``docs/HTTP_API.md`` documents every route and a test diffs the two):

  - **keep-alive + pipelining**: connections stay open across requests
    (HTTP/1.1 semantics, ``Connection: close`` honored); requests are
    read and answered strictly in order, so classic HTTP pipelining works.
  - **range reads**: ``Range: bytes=`` on file and tensor GETs — a
    cold-start loader fetches a tensor *slice*, not the 10 GB shard. The
    object is decoded once (single-flight + response cache) and sliced
    from the cached buffer; multi-range requests fall back to a full 200;
    unsatisfiable ranges get 416.
  - **conditional GETs**: file and tensor GETs carry a strong ``ETag``
    (the store's ``key@gN`` entity tag — generations are immutable, so
    HTTP caching is free correctness) plus ``Cache-Control: no-cache``;
    ``If-None-Match`` revalidation answers a bodiless 304, evaluated
    before ``Range`` per RFC 9110. Failover reads order replicas
    strongest-validator-first and schedule read-repair on divergence.
  - **zero-copy sendfile**: tensors whose payload is a ``stored``-codec
    frame (raw bytes the entropy stage could not shrink) are served —
    full or ranged — straight from the container file with
    ``os.sendfile``; no decode, no userspace copy.
  - **remote writes**: ``PUT /repo/<id>/file/<name>`` streams the upload
    to the owning root's spool and enqueues it on the store's pipelined
    ingest engine; ``POST /ingest_repo`` enqueues a server-local repo
    directory. ``/admin/jobs`` exposes job status; ``?sync=1`` blocks the
    request until its job finishes.

* :class:`ServerThread` — runs the server on a private event loop in a
  daemon thread, for synchronous harnesses (tests, benches, the soak).

Run standalone (repeat ``--root`` for a sharded multi-store node)::

    PYTHONPATH=src python -m repro.serve.store_server --root /srv/zllm-a \
        [--root /srv/zllm-b ...]
"""

from __future__ import annotations

import argparse
import asyncio
import hashlib
import json
import os
import re
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple
from urllib.parse import parse_qs, unquote, urlsplit

from repro.core.bitx import TMP_SUFFIX
from repro.core.lifecycle import make_vid
from repro.core.pipeline import ZLLMStore, _LRUCache
from repro.serve.router import QuorumError, StoreRouter
from repro.serve.singleflight import SingleFlight, TieredResponseCache

__all__ = ["RetrievalEngine", "StoreServer", "ServerThread", "ROUTES", "main"]

_REASONS = {200: "OK", 202: "Accepted", 206: "Partial Content",
            304: "Not Modified",
            400: "Bad Request", 404: "Not Found", 405: "Method Not Allowed",
            409: "Conflict", 410: "Gone", 411: "Length Required",
            416: "Range Not Satisfiable", 500: "Internal Server Error",
            503: "Service Unavailable"}

# Canonical route registry: (methods, path template, one-line summary).
# docs/HTTP_API.md must list EXACTLY these rows — tests/test_docs.py diffs
# the documented table against this tuple, so neither can rot alone.
ROUTES: Tuple[Tuple[str, str, str], ...] = (
    ("GET", "/healthz",
     "liveness + read generation(s)"),
    ("GET", "/stats",
     "engine + store counters; per-root sections under a multi-root router"),
    ("GET", "/repo/{repo_id}/file/{filename}",
     "bit-exact safetensors file; Range: bytes= supported"),
    ("PUT", "/repo/{repo_id}/file/{filename}",
     "remote write: spool the body, enqueue pipelined ingest"),
    ("GET", "/repo/{repo_id}/tensor/{tensor_name}",
     "one tensor's raw little-endian bytes; Range + ?name= query form"),
    ("POST", "/ingest_repo",
     "enqueue a server-local repo directory for ingest"),
    ("GET", "/admin/jobs",
     "spooled-ingest job status (?job=<id> for one)"),
    ("GET|POST", "/admin/gc",
     "garbage collection; ?incremental=1&max_pause_ms=; per root or all"),
    ("GET|POST", "/admin/compact",
     "dedup-aware compaction of superseded generations; per root or all"),
    ("GET|POST", "/admin/fsck",
     "integrity check; ?repair=1&spot_check=; per root or all"),
    ("GET|POST", "/admin/anti_entropy",
     "replica repair sweep: tombstones, quarantine-restore, re-ship diffs"),
    ("GET", "/peer/index_digest",
     "replication snapshot: per-key records, tombstones, version graph"),
    ("GET", "/peer/container/{key@gN}",
     "one container version's verbatim bytes (?digest=1 for sha256 only)"),
    ("POST", "/peer/adopt",
     "adopt shipped bytes: resumable container/restore upload or index record"),
    ("POST", "/peer/tombstones",
     "union a batch of (key, gen, ts) tombstones into the local store"),
    ("DELETE", "/repo/{repo_id}/file/{filename}",
     "tombstoned delete of one file on every replica (idempotent)"),
    ("DELETE", "/repo/{repo_id}",
     "tombstoned delete of a whole repo on every replica (idempotent)"),
)

# STRICT ASCII grammars (RFC 9110 range-spec is 1*DIGIT). Python's int()
# is far laxer than the ABNF — it accepts "+5", "1_0", surrounding
# whitespace and unicode digits (and bare \d matches unicode digits too),
# so grammar-invalid specs like "bytes=-1_0" used to parse and answer 206.
_RANGE_RE = re.compile(r"^([0-9]+)-([0-9]*)$", re.ASCII)
_SUFFIX_RANGE_RE = re.compile(r"^-([0-9]+)$", re.ASCII)
_MAX_JSON_BODY = 1 << 20        # POST bodies are control-plane JSON only
_UPLOAD_CHUNK = 1 << 20         # PUT spool streaming granularity


def quote_etag(tag: str) -> str:
    """``key@gN`` -> the quoted strong validator on the wire."""
    return f'"{tag}"'


def if_none_match_hit(header: Optional[str], etag: str) -> bool:
    """RFC 9110 §13.1.2 ``If-None-Match`` evaluation against one current
    entity tag (already quoted). ``*`` matches any current representation;
    the list form compares member by member with *weak comparison* — a
    ``W/``-prefixed copy of a tag still matches it."""
    if not header:
        return False
    header = header.strip()
    if header == "*":
        return True
    for cand in header.split(","):
        cand = cand.strip()
        if cand.startswith("W/"):
            cand = cand[2:]
        if cand == etag:
            return True
    return False


def _span_sha256_ok(path: str, offset: int, size: int, expect: str) -> bool:
    """sha256 a container frame span against its record hash (the
    sendfile path's one-time verification; runs on the executor)."""
    h = hashlib.sha256()
    try:
        with open(path, "rb") as f:
            f.seek(offset)
            remaining = size
            while remaining > 0:
                chunk = f.read(min(_UPLOAD_CHUNK, remaining))
                if not chunk:
                    return False
                h.update(chunk)
                remaining -= len(chunk)
    except OSError:
        return False
    return h.hexdigest() == expect


def parse_byte_range(header: Optional[str], size: int):
    """RFC-7233 single-range parser for ``Range: bytes=...``.

    Returns ``None`` (serve the full body: no/malformed header, or a
    multi-range request — rejected with a 200-full fallback by design),
    ``"unsat"`` (416: first-pos past the end, or an empty suffix), or an
    inclusive ``(start, end)`` with ``end`` clamped to ``size - 1``.
    """
    if not header or not header.startswith("bytes="):
        return None
    spec = header[len("bytes="):].strip()
    if "," in spec:
        return None  # multi-range: fall back to the full representation
    sm = _SUFFIX_RANGE_RE.match(spec)
    if sm is not None:  # suffix form: last N bytes
        n = int(sm.group(1))
        if n <= 0 or size == 0:
            return "unsat"
        return max(0, size - n), size - 1
    m = _RANGE_RE.match(spec)
    if m is None:
        return None
    start = int(m.group(1))
    end = int(m.group(2)) if m.group(2) else size - 1
    if start >= size:
        return "unsat"
    if end < start:
        return None
    return start, min(end, size - 1)


class _Request:
    """One parsed request on a keep-alive connection."""

    __slots__ = ("method", "target", "version", "headers", "reader", "keep")

    def __init__(self, method: str, target: str, version: str,
                 headers: Dict[str, str], reader: asyncio.StreamReader):
        self.method = method
        self.target = target
        self.version = version
        self.headers = headers
        self.reader = reader
        conn = headers.get("connection", "").lower()
        self.keep = (conn != "close" if version == "HTTP/1.1"
                     else conn == "keep-alive")


class RetrievalEngine:
    """Concurrent retrieval over one :class:`ZLLMStore`.

    Loop-confined: construct and call from a single event loop. The store
    may be mutated concurrently from *other* threads (ingest, delete, gc) —
    that is the supported serving topology; what is not supported is two
    engines fronting one store from two loops with one response cache.
    """

    def __init__(self, store: ZLLMStore, *, max_concurrency: int = 8,
                 cache_bytes: int = 128 << 20,
                 spill_bytes: Optional[int] = None, verify: bool = True):
        self.store = store
        self.verify = verify
        self._pool = ThreadPoolExecutor(max_workers=max(1, max_concurrency),
                                        thread_name_prefix="zllm-serve")
        self._flight = SingleFlight()
        # cache_bytes <= 0 disables response caching entirely (the serving
        # bench measures concurrent decodes, not cache hits). Otherwise the
        # two-tier cache: RAM LRU + decoded-spill files under the store
        # root, keyed by (object, entity tag) — see TieredResponseCache.
        # spill_bytes <= 0 keeps the RAM tier but disables the disk tier;
        # None sizes it at the TieredResponseCache default (4x RAM).
        if cache_bytes > 0:
            spill_dir = (None if (spill_bytes is not None and spill_bytes <= 0)
                         else store.decoded_dir())
            self._cache = TieredResponseCache(
                spill_dir, max_bytes=cache_bytes,
                spill_max_bytes=(spill_bytes if spill_bytes is not None
                                 and spill_bytes > 0 else None),
                max_items=1024)
        else:
            self._cache = None
        self._cache_gen = -1  # read_gen the cache was last validated at
        self.requests = 0
        self.errors = 0

    # -- retrieval ------------------------------------------------------
    async def get_file(self, repo_id: str, filename: str = "model.safetensors") -> bytes:
        """Bit-exact safetensors bytes for ``repo_id/filename``."""
        data, _ = await self.get_file_digest(repo_id, filename)
        return data

    async def get_file_digest(self, repo_id: str,
                              filename: str = "model.safetensors") -> Tuple[bytes, str]:
        """(bytes, sha256 hexdigest). The digest comes from the store's own
        gate-held decode (one hash per flight, on the executor, always
        consistent with the returned bytes) and is cached with the
        response — never recomputed per request on the event loop."""
        return await self._fetch(
            ("file", repo_id, filename),
            lambda: self.store.retrieve_file_digest(repo_id, filename,
                                                    verify=self.verify))

    async def get_tensor(self, repo_id: str, tensor_name: str,
                         filename: str = "model.safetensors") -> Tuple[bytes, Dict]:
        """One tensor's raw bytes + metadata for ``repo_id/filename``.
        Ranged HTTP reads slice the bytes returned here — the decode runs
        (and is cached, and single-flighted) ONCE per object per read
        generation no matter how many slices are requested."""
        return await self._fetch(
            ("tensor", repo_id, filename, tensor_name),
            lambda: self.store.retrieve_tensor(repo_id, filename, tensor_name,
                                               verify=self.verify))

    async def _fetch(self, key: Tuple, call):
        """Cache → single-flight → executor.

        Cache entries are keyed by the object's strong validator (the
        entity tag conditional GETs revalidate against), so an unrelated
        mutation no longer wipes every hot object — only entries whose
        OWN key was re-registered / deleted go stale, and those are
        purged the first time a ``read_gen`` change is observed. Flights
        still include the read_gen (snapshot isolation: a request issued
        after a mutation never coalesces onto a stale in-flight decode),
        and a decode that outlives a re-registration of its key is
        re-validated before insertion — a slow flight completing after a
        gen bump must not park dead bytes on the budget (the
        stale-generation leak regression)."""
        self.requests += 1
        gen = self.store.read_gen
        tag = self.store.entity_tag(key[1], key[2])
        if self._cache is not None:
            if gen != self._cache_gen:
                self._cache.purge(self._entry_current)
                self._cache_gen = gen
            if tag is not None:
                hit = self._cache.get(key, tag)
                if hit is not None:
                    return hit
        loop = asyncio.get_running_loop()

        async def thunk():
            return await loop.run_in_executor(self._pool, call)

        try:
            result = await self._flight.run((gen, tag) + key, thunk)
        except Exception:
            self.errors += 1
            raise
        if (self._cache is not None and tag is not None
                and self.store.entity_tag(key[1], key[2]) == tag):
            nbytes = len(result[0]) if isinstance(result, tuple) else len(result)
            self._cache.put(key, tag, result, nbytes)
        return result

    def _entry_current(self, objkey: Tuple, validator: str) -> bool:
        """Is a cache entry's validator still the one its key serves?
        ``objkey[1:3]`` is ``(repo_id, filename)`` for both object kinds."""
        return self.store.entity_tag(objkey[1], objkey[2]) == validator

    # -- admin ----------------------------------------------------------
    # These are the single-store *embedding* API (callers holding an
    # engine directly — see the serve README). The HTTP /admin/* routes
    # fan out through StoreRouter.fanout_* instead, so they cover every
    # root of a sharded node with one call.
    async def run_gc(self, incremental: bool = False,
                     max_pause_ms: float = 50.0) -> Dict[str, int]:
        """Run ``store.gc()`` off-loop. Safe during serving AND during an
        ingest batch on another thread: gc serializes behind the store's
        admin lock, its write gate drains in-flight decodes, and read_gen
        rolls the engine caches over. ``incremental=True`` sweeps in
        bounded steps (target ``max_pause_ms`` exclusive hold each) that
        interleave with the live traffic instead of stopping the world."""
        return await asyncio.get_running_loop().run_in_executor(
            self._pool, lambda: self.store.gc(incremental=incremental,
                                              max_pause_ms=max_pause_ms))

    async def run_compact(self) -> Dict:
        """Run ``store.compact()`` off-loop: rewrite still-referenced
        records out of superseded generations and retire them. The byte
        copying runs concurrently with serving; only the final pointer
        swap holds the read gate (reported as ``exclusive_hold_ms``)."""
        return await asyncio.get_running_loop().run_in_executor(
            self._pool, self.store.compact)

    def stats(self) -> Dict:
        return {
            "requests": self.requests,
            "errors": self.errors,
            "read_gen": self.store.read_gen,
            "singleflight": self._flight.stats(),
            "response_cache": (self._cache.stats()
                               if self._cache is not None else {"disabled": True}),
            "workers": self._pool._max_workers,
            "verify": self.verify,
        }

    async def aclose(self) -> None:
        await asyncio.get_running_loop().run_in_executor(
            None, lambda: self._pool.shutdown(wait=True))


class StoreServer:
    """HTTP/1.1 front (keep-alive, ranges, remote writes, sendfile) over
    one :class:`RetrievalEngine` per routed store root."""

    def __init__(self, store, host: str = "127.0.0.1", port: int = 0,
                 *, max_concurrency: int = 8, cache_bytes: int = 128 << 20,
                 spill_bytes: Optional[int] = None, verify: bool = True,
                 idle_timeout: float = 30.0):
        self.router = (store if isinstance(store, StoreRouter)
                       else StoreRouter(store))
        # engines decode from LOCAL stores only: a PeerStore root (remote
        # replica) holds no mmap-able containers here — its own server
        # decodes for its own clients
        self.engines: Dict[str, RetrievalEngine] = {
            name: RetrievalEngine(s, max_concurrency=max_concurrency,
                                  cache_bytes=cache_bytes,
                                  spill_bytes=spill_bytes, verify=verify)
            for name, s in self.router.items()
            if not getattr(s, "is_peer", False)}
        if not self.engines:
            raise ValueError("StoreServer needs at least one local "
                             "(non-peer) store root to serve from")
        # back-compat: the single-root engine (first root's under a router)
        self.engine = next(iter(self.engines.values()))
        self.idle_timeout = idle_timeout
        self._host_arg, self._port_arg = host, port
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        self._server: Optional[asyncio.AbstractServer] = None
        # HTTP-layer counters (the engine counts decodes; these count the
        # protocol surface: connections reused, ranges, zero-copy sends)
        self.http = {"connections": 0, "requests": 0, "range_requests": 0,
                     "sendfile_responses": 0, "put_uploads": 0,
                     "put_bytes": 0,
                     # conditional GETs: requests carrying If-None-Match,
                     # and how many revalidated to a bodiless 304
                     "conditional_requests": 0, "not_modified": 0}
        # live keep-alive connections: handler tasks park on readline
        # between requests, so shutdown must actively close their
        # transports or the loop teardown reports destroyed pending tasks
        self._conns: set = set()
        # sendfile spans sha256-checked once (verify=True): containers are
        # immutable, so (path, offset) never needs re-verification. LRU,
        # not a set — retired generations must not accumulate forever
        self._verified_spans = _LRUCache(max_items=4096)
        # span-or-None verdict per (read_gen, root, object): the probe
        # takes the store read gate and opens a container reader, so hot
        # non-stored tensors must not pay it on every keep-alive request
        self._span_cache = _LRUCache(max_items=4096)

    def engine_for(self, repo_id: str,
                   filename: str = "model.safetensors") -> RetrievalEngine:
        return self.engines[self.router.locate(repo_id, filename)]

    async def start(self) -> Tuple[str, int]:
        self._server = await asyncio.start_server(self._handle, self._host_arg,
                                                  self._port_arg)
        self.host, self.port = self._server.sockets[0].getsockname()[:2]
        return self.host, self.port

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for task in list(self._conns):  # wake idle keep-alive handlers
            task.cancel()
        if self._conns:
            await asyncio.gather(*self._conns, return_exceptions=True)
        for engine in self.engines.values():
            await engine.aclose()

    # -- connection handling ----------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        """One connection, N requests: the keep-alive loop. Requests are
        parsed and answered strictly in order (pipelined clients get their
        responses in request order); the loop ends on ``Connection:
        close``, client EOF, idle timeout, or an error that leaves the
        request framing in an unknown state."""
        self.http["connections"] += 1
        self._conns.add(asyncio.current_task())
        try:
            while True:
                req = await self._read_request(reader)
                if req is None:
                    break
                self.http["requests"] += 1
                try:
                    await self._route(writer, req)
                except (ConnectionError, asyncio.TimeoutError):
                    raise
                except Exception as e:  # handler bug: answer 500, drop conn
                    req.keep = False
                    await self._respond(writer, 500,
                                        {"error": f"{type(e).__name__}: {e}"},
                                        keep=False)
                if not req.keep:
                    break
        except (asyncio.TimeoutError, ConnectionError):
            pass
        except asyncio.CancelledError:
            pass  # server shutdown: drop the connection quietly
        except ValueError:
            # oversized request/header line (StreamReader limit overrun) —
            # answer 400 instead of leaking an unhandled task exception
            try:
                await self._respond(writer, 400,
                                    {"error": "request line or headers too large"},
                                    keep=False)
            except Exception:
                pass
        finally:
            self._conns.discard(asyncio.current_task())
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError, asyncio.CancelledError):
                pass

    async def _read_request(self, reader: asyncio.StreamReader) -> Optional[_Request]:
        request = await asyncio.wait_for(reader.readline(),
                                         timeout=self.idle_timeout)
        if not request:
            return None  # clean EOF between requests
        parts = request.decode("latin-1").split()
        if len(parts) < 2:
            return None
        method, target = parts[0].upper(), parts[1]
        version = parts[2] if len(parts) > 2 else "HTTP/1.0"
        headers: Dict[str, str] = {}
        while True:
            line = await asyncio.wait_for(reader.readline(), timeout=30)
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        return _Request(method, target, version, headers, reader)

    async def _drain_body(self, req: _Request) -> None:
        """Consume an unread request body so the next request on the
        connection parses cleanly; closes instead when the body is
        unbounded (chunked) or oversized."""
        te = req.headers.get("transfer-encoding", "").lower()
        if "chunked" in te:
            req.keep = False
            return
        try:
            length = int(req.headers.get("content-length", "0"))
        except ValueError:
            req.keep = False
            return
        if length > 64 << 20:  # refuse to slurp huge bodies just for framing
            req.keep = False
            return
        while length > 0:
            chunk = await asyncio.wait_for(
                req.reader.read(min(_UPLOAD_CHUNK, length)), timeout=60)
            if not chunk:
                req.keep = False
                return
            length -= len(chunk)

    # -- routing ------------------------------------------------------------
    async def _route(self, writer, req: _Request) -> None:
        url = urlsplit(req.target)
        segs = [unquote(s) for s in url.path.split("/") if s]
        qs = parse_qs(url.query)
        is_file_route = len(segs) >= 4 and segs[0] == "repo" and segs[-2] == "file"
        try:
            if req.method == "PUT":
                if is_file_route:
                    await self._put_file(writer, req, segs, qs)
                else:
                    await self._drain_body(req)
                    await self._respond(writer, 405,
                                        {"error": "PUT only on "
                                         "/repo/<repo_id>/file/<filename>"},
                                        keep=req.keep)
                return
            if req.method == "DELETE":
                await self._drain_body(req)
                if is_file_route:
                    out = self.router.delete("/".join(segs[1:-2]), segs[-1])
                elif len(segs) >= 2 and segs[0] == "repo":
                    out = self.router.delete("/".join(segs[1:]))
                else:
                    await self._respond(writer, 405,
                                        {"error": "DELETE only on /repo/"
                                         "<repo_id>[/file/<filename>]"},
                                        keep=req.keep)
                    return
                await self._respond(writer, 200, out, keep=req.keep)
                return
            if req.method == "POST":
                if url.path == "/ingest_repo":
                    await self._ingest_repo(writer, req)
                elif url.path == "/peer/adopt":
                    # streams its own body (resumable ship): NOT pre-drained
                    await self._peer_adopt(writer, req, qs)
                elif url.path == "/peer/tombstones":
                    await self._peer_tombstones(writer, req)
                elif url.path.startswith("/admin/"):
                    await self._drain_body(req)
                    await self._admin(writer, req, url.path, qs)
                else:
                    await self._drain_body(req)
                    await self._respond(writer, 405,
                                        {"error": "POST only on /ingest_repo, "
                                         "/peer/*, and /admin/*"},
                                        keep=req.keep)
                return
            if req.method != "GET":
                await self._drain_body(req)
                await self._respond(writer, 405,
                                    {"error": f"method {req.method} not "
                                     f"supported"}, keep=req.keep)
                return
            await self._drain_body(req)  # tolerate (and skip) GET bodies
            if url.path == "/healthz":
                single = self.router.single
                gen = (single.read_gen if single is not None else
                       {n: s.read_gen for n, s in self.router.items()})
                health = self.router.health()
                await self._respond(writer, 200,
                                    {"ok": all(h["state"] != "down"
                                               for h in health.values()),
                                     "read_gen": gen,
                                     "roots": self.router.names(),
                                     "health": health,
                                     "replicas": self.router.replicas,
                                     "write_quorum": self.router.write_quorum},
                                    keep=req.keep)
            elif url.path == "/stats":
                await self._stats(writer, req)
            elif url.path == "/peer/index_digest":
                await self._peer_index_digest(writer, req)
            elif len(segs) >= 3 and segs[0] == "peer" and segs[1] == "container":
                await self._peer_container(writer, req, "/".join(segs[2:]), qs)
            elif url.path.startswith("/admin/"):
                await self._admin(writer, req, url.path, qs)
            elif is_file_route:
                repo_id, filename = "/".join(segs[1:-2]), segs[-1]
                inm = req.headers.get("if-none-match")
                if inm:
                    self.http["conditional_requests"] += 1

                async def file_attempt(engine):
                    # conditional evaluation FIRST (RFC 9110 §13.2.2:
                    # If-None-Match precedes Range): a validator match
                    # answers 304 with no decode at all — also on ranged
                    # requests
                    tag = engine.store.entity_tag(repo_id, filename)
                    if tag is not None and if_none_match_hit(
                            inm, quote_etag(tag)):
                        return None, None, tag
                    data, sha = await engine.get_file_digest(repo_id,
                                                             filename)
                    return data, sha, (engine.store.entity_tag(
                        repo_id, filename) or tag)

                (data, sha, tag), served_by = await self._with_failover(
                    repo_id, filename, file_attempt)
                engine = self.engines[served_by]
                cond = self._etag_headers(tag)
                if data is None:  # revalidated: bodiless 304
                    self.http["not_modified"] += 1
                    await self._write(
                        writer, 304, b"", "application/octet-stream",
                        cond + [("x-read-gen", str(engine.store.read_gen)),
                                ("x-served-by", served_by)], req.keep)
                else:
                    await self._respond_ranged(
                        writer, req, data,
                        [("x-content-sha256", sha),
                         ("x-read-gen", str(engine.store.read_gen)),
                         ("x-served-by", served_by)] + cond)
            elif (len(segs) >= 3 and segs[0] == "repo" and segs[-1] == "tensor"
                  and "name" in qs):
                # unambiguous form: /repo/<repo_id>/tensor?name=<tensor> —
                # for names where the path grammar below would mis-split
                repo_id = "/".join(segs[1:-1])
                await self._tensor_get(writer, req, repo_id, qs["name"][0],
                                       qs.get("file", ["model.safetensors"])[0])
            elif len(segs) >= 4 and segs[0] == "repo" and "tensor" in segs[2:-1]:
                # path form: rightmost "tensor" marker splits repo id from
                # tensor name (both may contain slashes; a tensor name with
                # a literal "tensor" segment needs the ?name= form above)
                i = len(segs) - 1 - segs[::-1].index("tensor")
                repo_id = "/".join(segs[1:i])
                tensor_name = "/".join(segs[i + 1:])
                filename = qs.get("file", ["model.safetensors"])[0]
                await self._tensor_get(writer, req, repo_id, tensor_name,
                                       filename)
            else:
                await self._respond(writer, 404,
                                    {"error": f"no route for {url.path}"},
                                    keep=req.keep)
        except KeyError as e:
            self._fail_framing(req)
            await self._respond(writer, 404, {"error": str(e)}, keep=req.keep)
        except QuorumError as e:
            # before ConnectionError: QuorumError subclasses it, but it is
            # an HTTP-visible replication failure, not a dead client socket
            self._fail_framing(req)
            await self._respond(writer, 503, {"error": str(e)}, keep=req.keep)
        except RuntimeError as e:
            self._fail_framing(req)
            status = 410 if "quarantined" in str(e) else 500
            await self._respond(writer, status, {"error": str(e)}, keep=req.keep)
        except (ConnectionError, asyncio.TimeoutError):
            raise
        except Exception as e:  # backend mismatch, decode failure, ...
            self._fail_framing(req)
            await self._respond(writer, 500,
                                {"error": f"{type(e).__name__}: {e}"},
                                keep=req.keep)

    @staticmethod
    def _fail_framing(req: _Request) -> None:
        """An upload handler failed somewhere its body may not have been
        fully read (e.g. before the PUT spool loop): the connection's
        request framing is unknown, so it must close after the error
        response. GET bodies were drained up front and stay keep-alive."""
        if req.method != "GET":
            req.keep = False

    # -- read path ----------------------------------------------------------
    async def _with_failover(self, repo_id: str, filename: str, attempt):
        """Run ``attempt(engine)`` against each read candidate in replica
        order until one serves; returns ``(result, root_name)``. A down or
        erroring root is skipped (and its failure noted, feeding the
        router's suspect backoff); a quarantined container is skipped
        WITHOUT a health mark — the root is fine, that one object is not.
        Exhaustion re-raises the most specific failure: 410 when a healthy
        copy exists nowhere but a quarantined one does, 404 when no replica
        knows the key, otherwise the last hard error.

        Candidates come from the router's :meth:`read_plan`, which orders
        the ready tier strongest-record-first, so a failover read never
        serves a weaker validator while a stronger replica is ready. A
        read that had to skip a replica — or whose group the plan saw
        divergent — schedules an asynchronous per-repo read-repair on the
        store's job worker instead of waiting for a full sweep."""
        names, divergent = self.router.read_plan(repo_id, filename)
        if not names:
            raise QuorumError(f"no replica of {repo_id} is up")
        key_errors = 0
        quarantined: Optional[Exception] = None
        hard: Optional[Exception] = None
        skipped_peers = 0
        for name in names:
            engine = self.engines.get(name)
            if engine is None:  # remote peer replica: no local bytes to
                skipped_peers += 1  # decode — its own server serves reads
                continue
            try:
                out = await attempt(engine)
            except KeyError as e:
                key_errors += 1
                last_key = e
                continue
            except RuntimeError as e:
                if "quarantined" in str(e):
                    quarantined = e
                else:
                    self.router.note_failure(name)
                    hard = e
                continue
            except (ConnectionError, asyncio.TimeoutError):
                raise
            except Exception as e:
                self.router.note_failure(name)
                hard = e
                continue
            self.router.note_success(name)
            if divergent or key_errors or quarantined is not None \
                    or hard is not None:
                self.router.schedule_read_repair(
                    repo_id,
                    note=f"read-repair: {repo_id} served by {name}"
                         f"{' (divergent group)' if divergent else ''}")
            return out, name
        if quarantined is not None and hard is None:
            raise quarantined
        if hard is not None:
            raise hard
        if key_errors == 0:
            raise QuorumError(
                f"no local replica of {repo_id} can serve reads "
                f"({skipped_peers} remote peer(s) skipped)")
        raise last_key  # every replica answered KeyError -> 404

    async def _tensor_get(self, writer, req: _Request, repo_id: str,
                          tensor_name: str, filename: str) -> None:
        async def attempt(engine):
            await self._tensor_serve(writer, req, engine, repo_id,
                                     tensor_name, filename)
            return True
        await self._with_failover(repo_id, filename, attempt)

    async def _tensor_serve(self, writer, req: _Request,
                            engine: RetrievalEngine, repo_id: str,
                            tensor_name: str, filename: str) -> None:
        # conditional evaluation FIRST (RFC 9110: If-None-Match precedes
        # Range): tensors share the file's (key, gen) validator — a match
        # revalidates without touching the span probe or the decode path.
        inm = req.headers.get("if-none-match")
        tag = engine.store.entity_tag(repo_id, filename)
        if inm:
            self.http["conditional_requests"] += 1
            if tag is not None and if_none_match_hit(inm, quote_etag(tag)):
                self.http["not_modified"] += 1
                await self._write(
                    writer, 304, b"", "application/octet-stream",
                    self._etag_headers(tag)
                    + [("x-read-gen", str(engine.store.read_gen))],
                    req.keep)
                return
        # zero-copy short-circuit: a `stored`-codec payload is a verbatim
        # on-disk span — full and ranged responses go through os.sendfile,
        # no decode, no userspace copy. Any irregularity (codec, race with
        # a concurrent compact/gc unlink) falls back to the decode path.
        # The span-or-None verdict is memoized per read generation: the
        # probe holds the read gate and opens a reader, which hot
        # non-stored tensors must not pay per keep-alive request.
        sk = (engine.store.read_gen, id(engine), repo_id, filename,
              tensor_name)
        span = self._span_cache.get(sk)
        if span is None:
            span = await asyncio.get_running_loop().run_in_executor(
                engine._pool, engine.store.tensor_sendfile_span,
                repo_id, filename, tensor_name)
            self._span_cache.put(sk, span if span is not None else "none")
        elif span == "none":
            span = None
        if span is not None:
            if await self._respond_sendfile(writer, req, engine, span, tag):
                return
        data, meta = await engine.get_tensor(repo_id, tensor_name, filename)
        await self._respond_ranged(writer, req, data,
                                   self._tensor_headers(engine, meta, tag))

    @staticmethod
    def _etag_headers(tag: Optional[str]) -> List[Tuple[str, str]]:
        """ETag + revalidation policy. ``no-cache`` means "store, but
        revalidate before reuse" — the right policy for immutable
        generations behind a mutable key: revalidation is a free 304
        until the key is re-registered, then the new bytes flow."""
        if not tag:
            return []
        return [("etag", quote_etag(tag)), ("cache-control", "no-cache")]

    @classmethod
    def _tensor_headers(cls, engine: RetrievalEngine, meta: Dict,
                        tag: Optional[str] = None) -> List[Tuple[str, str]]:
        return [("x-tensor-dtype", meta["dtype"]),
                ("x-tensor-shape", json.dumps(meta["shape"])),
                ("x-tensor-codec", meta["codec"]),
                ("x-read-gen", str(engine.store.read_gen))] \
            + cls._etag_headers(tag)

    async def _respond_sendfile(self, writer, req: _Request,
                                engine: RetrievalEngine, span,
                                tag: Optional[str] = None) -> bool:
        """Serve a stored-codec frame span with ``os.sendfile``; returns
        False (caller falls back to the decode path) when the container
        vanished between span resolution and open — the one benign race.
        Once the fd is open the transfer is safe regardless of concurrent
        gc/compact: container files are immutable and the fd keeps the
        bytes alive across an unlink."""
        cpath, offset, size, meta = span
        if engine.verify and self._verified_spans.get((cpath, offset)) is None:
            # first touch of this span under verify=True: one sha256 pass
            # against the record's ingest-time hash (on the executor).
            # Immutable containers make the memo sound; a mismatch (bit
            # rot) falls back to the decode path, which raises the proper
            # verification error -> 500, same as every other codec.
            ok = await asyncio.get_running_loop().run_in_executor(
                engine._pool, _span_sha256_ok, cpath, offset, size,
                meta["sha256"])
            if not ok:
                return False
            self._verified_spans.put((cpath, offset), True)
        rng = parse_byte_range(req.headers.get("range"), size)
        if rng == "unsat":
            await self._respond(writer, 416,
                                {"error": f"range out of bounds for "
                                 f"{size}-byte tensor"},
                                keep=req.keep,
                                extra=[("content-range", f"bytes */{size}")])
            return True
        try:
            f = open(cpath, "rb")
        except OSError:
            return False
        try:
            start, end = rng if rng is not None else (0, size - 1)
            count = end - start + 1
            status = 206 if rng is not None else 200
            if rng is not None:
                self.http["range_requests"] += 1
            extra = self._tensor_headers(engine, meta, tag)
            extra.append(("x-zllm-sendfile", "1"))
            if status == 206:
                extra.append(("content-range", f"bytes {start}-{end}/{size}"))
            head = self._head(status, count, "application/octet-stream",
                              extra, req.keep)
            writer.write(head)
            await writer.drain()
            loop = asyncio.get_running_loop()
            try:
                await loop.sendfile(writer.transport, f, offset + start,
                                    count, fallback=True)
            except (ConnectionError, asyncio.TimeoutError):
                raise
            except Exception as e:
                # head (and possibly part of the body) is on the wire: no
                # JSON may follow under this content-length — drop the
                # connection instead of desyncing the client
                raise ConnectionError(f"sendfile failed mid-body: {e}") from e
            self.http["sendfile_responses"] += 1
            return True
        finally:
            f.close()

    async def _respond_ranged(self, writer, req: _Request, data: bytes,
                              extra: List[Tuple[str, str]]) -> None:
        """Full (200) or single-range (206) byte response; 416 with
        ``content-range: bytes */N`` when unsatisfiable. The full object
        was decoded once into the engine's response cache — every slice is
        a view of that buffer."""
        size = len(data)
        rng = parse_byte_range(req.headers.get("range"), size)
        if rng == "unsat":
            await self._respond(writer, 416,
                                {"error": f"range out of bounds for "
                                 f"{size}-byte body"},
                                keep=req.keep,
                                extra=[("content-range", f"bytes */{size}")])
            return
        if rng is None:
            await self._write(writer, 200, data, "application/octet-stream",
                              extra, req.keep)
            return
        start, end = rng
        self.http["range_requests"] += 1
        body = memoryview(data)[start:end + 1]
        await self._write(writer, 206, body, "application/octet-stream",
                          extra + [("content-range",
                                    f"bytes {start}-{end}/{size}")],
                          req.keep)

    # -- write path ----------------------------------------------------------
    async def _put_file(self, writer, req: _Request, segs: List[str],
                        qs: Dict[str, List[str]]) -> None:
        """Remote write: stream the body to the owning root's spool, then
        enqueue it on the store's pipelined ingest engine. 202 + job id by
        default; ``?sync=1`` waits for the job and returns its result.
        ``?base=<base_id>`` forwards a declared BitX base."""
        repo_id, filename = "/".join(segs[1:-2]), segs[-1]
        if "chunked" in req.headers.get("transfer-encoding", "").lower() \
                or "content-length" not in req.headers:
            req.keep = False
            await self._respond(writer, 411,
                                {"error": "content-length required "
                                 "(chunked uploads not supported)"},
                                keep=False)
            return
        try:
            length = int(req.headers["content-length"])
        except ValueError:
            req.keep = False
            await self._respond(writer, 400, {"error": "bad content-length"},
                                keep=False)
            return
        if length <= 0:
            await self._respond(writer, 400,
                                {"error": "empty upload"}, keep=req.keep)
            return
        base = qs.get("base", [None])[0]
        # family-aware placement: a new repo declaring a BitX base lands on
        # the root group serving that base (per-root delta domains — a
        # scattered family would store every fine-tune standalone). The
        # body spools into the first write target; replicated_enqueue
        # stages per-replica copies from there.
        targets = self.router.write_roots(repo_id, filename, base=base)
        root = targets[0]
        store = self.router.store(root)
        fd, spath = tempfile.mkstemp(
            prefix="put-", suffix="-" + filename.replace("/", "_"),
            dir=store.spool_dir())
        received = 0
        loop = asyncio.get_running_loop()
        try:
            with os.fdopen(fd, "wb") as f:
                while received < length:
                    chunk = await asyncio.wait_for(
                        req.reader.read(min(_UPLOAD_CHUNK, length - received)),
                        timeout=120)
                    if not chunk:
                        raise ConnectionError("client closed mid-upload")
                    # disk writes go through the default executor: a
                    # multi-GB upload must not stall every other
                    # connection on each 1 MB write burst
                    await loop.run_in_executor(None, f.write, chunk)
                    received += len(chunk)
        except BaseException:
            try:
                os.remove(spath)
            except OSError:
                pass
            raise
        self.http["put_uploads"] += 1
        self.http["put_bytes"] += received
        # quorum fan-out (QuorumError -> 503 in the dispatcher); a
        # single-root router degenerates to the old one-job path exactly
        loop2 = asyncio.get_running_loop()
        rep = await loop2.run_in_executor(
            self.engine._pool,
            lambda: self.router.replicated_enqueue(spath, repo_id, filename,
                                                   base=base))
        first = next(iter(rep["jobs"]))
        if qs.get("sync", ["0"])[0] in ("0", "", "false"):
            out = {"job_id": rep["jobs"][first], "root": first,
                   "repo_id": repo_id, "filename": filename,
                   "bytes": received,
                   "status": f"/admin/jobs?job={rep['jobs'][first]}"}
            if len(rep["targets"]) > 1:
                out["replicas"] = {"jobs": rep["jobs"],
                                   "failed": rep["failed"],
                                   "quorum": rep["quorum"]}
            await self._respond(writer, 202, out, keep=req.keep)
            return
        ok, states = await loop2.run_in_executor(
            self.engine._pool, lambda: self.router.await_quorum(rep["jobs"]))
        job = states.get(first)
        if job is not None:
            job = dict(job)
            job.setdefault("root", first)
        status = 200 if ok else 500
        out = {"root": first, "job": job}
        if len(rep["targets"]) > 1:
            out["replicas"] = {"quorum_met": ok,
                               "states": {n: (s or {}).get("state")
                                          for n, s in states.items()},
                               "failed": rep["failed"]}
        await self._respond(writer, status, out, keep=req.keep)

    async def _ingest_repo(self, writer, req: _Request) -> None:
        """Enqueue a *server-local* repo directory (bulk feeding / sidecar
        drops): body is ``{"dir": ..., "repo_id": ..., "sync": bool}``.
        Metadata (config.json / README base_model) is parsed exactly as in
        local ``ingest_repos``."""
        te = req.headers.get("transfer-encoding", "").lower()
        try:
            length = int(req.headers.get("content-length", "0"))
        except ValueError:
            length = -1
        if "chunked" in te or length <= 0 or length > _MAX_JSON_BODY:
            req.keep = False
            await self._respond(writer, 411,
                                {"error": "JSON body with content-length "
                                 f"<= {_MAX_JSON_BODY} required"}, keep=False)
            return
        body = await asyncio.wait_for(req.reader.readexactly(length),
                                      timeout=60)
        try:
            spec = json.loads(body)
            repo_dir = spec["dir"]
        except (ValueError, KeyError, TypeError):
            await self._respond(writer, 400,
                                {"error": 'body must be {"dir": ..., '
                                 '"repo_id": ..., "sync": bool}'},
                                keep=req.keep)
            return
        if not os.path.isdir(repo_dir):
            await self._respond(writer, 404,
                                {"error": f"no such directory: {repo_dir}"},
                                keep=req.keep)
            return
        repo_id = spec.get("repo_id") or os.path.basename(
            os.path.normpath(repo_dir))
        root = self.router.locate(repo_id)
        store = self.router.store(root)
        job_id = store.enqueue_ingest_repo(repo_dir, repo_id)
        if not spec.get("sync"):
            await self._respond(writer, 202,
                                {"job_id": job_id, "root": root,
                                 "repo_id": repo_id,
                                 "status": f"/admin/jobs?job={job_id}"},
                                keep=req.keep)
            return
        job = await self._await_job(store, job_id)
        status = 200 if job and job["state"] == "done" else 500
        await self._respond(writer, status, {"root": root, "job": job},
                            keep=req.keep)

    @staticmethod
    async def _await_job(store: ZLLMStore, job_id: str,
                         timeout: float = 600.0) -> Optional[Dict]:
        """Poll one job to a terminal state without blocking the loop."""
        deadline = time.monotonic() + timeout
        while True:
            job = store.ingest_job(job_id)
            if job is None or job["state"] in ("done", "failed"):
                return job
            if time.monotonic() >= deadline:
                job["state"] = "timeout"
                return job
            await asyncio.sleep(0.02)

    # -- stats + admin --------------------------------------------------------
    async def _stats(self, writer, req: _Request) -> None:
        # store summaries walk index/lifecycle dicts — run them on the
        # executor so a slow store never stalls the event loop
        store_stats = await asyncio.get_running_loop().run_in_executor(
            self.engine._pool, self.router.summary)
        if self.router.single is not None:
            server = dict(self.engine.stats())
        else:
            server = {
                "requests": sum(e.requests for e in self.engines.values()),
                "errors": sum(e.errors for e in self.engines.values()),
                "roots": {name: e.stats() for name, e in self.engines.items()},
            }
        server["http"] = dict(self.http)
        await self._respond(writer, 200, {"server": server,
                                          "store": store_stats},
                            keep=req.keep)

    async def _admin(self, writer, req: _Request, path: str,
                     qs: Dict[str, List[str]]) -> None:
        loop = asyncio.get_running_loop()
        root = qs.get("root", [None])[0]
        if path == "/admin/jobs":
            job_id = qs.get("job", [None])[0]
            if job_id is not None:
                job = self.router.ingest_job(job_id)
                if job is None:
                    await self._respond(writer, 404,
                                        {"error": f"unknown job {job_id}"},
                                        keep=req.keep)
                else:
                    await self._respond(writer, 200, job, keep=req.keep)
            else:
                jobs = self.router.ingest_jobs()
                await self._respond(writer, 200, {"jobs": jobs}, keep=req.keep)
        elif path == "/admin/compact":
            # dedup-aware compaction: rewrite still-referenced records out
            # of superseded generations, retire the old gens. Runs on the
            # executor; serving continues except for the commit's bounded
            # exclusive hold (returned as exclusive_hold_ms).
            out = await loop.run_in_executor(
                self.engine._pool, lambda: self.router.fanout_compact(root))
            await self._respond(writer, 200, out, keep=req.keep)
        elif path == "/admin/gc":
            inc = qs.get("incremental", ["0"])[0].lower() not in ("0", "false", "")
            pause = float(qs.get("max_pause_ms", ["50"])[0])
            out = await loop.run_in_executor(
                self.engine._pool,
                lambda: self.router.fanout_gc(root, incremental=inc,
                                              max_pause_ms=pause))
            await self._respond(writer, 200, out, keep=req.keep)
        elif path == "/admin/fsck":
            repair = qs.get("repair", ["0"])[0].lower() not in ("0", "false", "")
            spot_raw = qs.get("spot_check", ["4"])[0]
            spot = None if spot_raw in ("all", "none", "") else int(spot_raw)
            out = await loop.run_in_executor(
                self.engine._pool,
                lambda: self.router.fanout_fsck(root, repair=repair,
                                                spot_check=spot))
            await self._respond(writer, 200, out, keep=req.keep)
        elif path == "/admin/anti_entropy":
            repos = qs.get("repo") or None
            out = await loop.run_in_executor(
                self.engine._pool,
                lambda: self.router.anti_entropy(repos=repos))
            out["diff_after"] = await loop.run_in_executor(
                self.engine._pool,
                lambda: self.router.replica_index_diff(repos=repos))
            await self._respond(writer, 200, out, keep=req.keep)
        else:
            await self._respond(writer, 404,
                                {"error": f"no admin route for {path}"},
                                keep=req.keep)

    # -- peer replication protocol --------------------------------------------
    # The wire form of the in-process ship/adopt primitives: a remote
    # StoreRouter's PeerStore client (repro.serve.peer) drives these four
    # routes to diff index state, pull/push verbatim container bytes
    # (sha256-authenticated, resumable via .part staging), adopt index
    # records dependencies-first, and union tombstones.

    def _local_stores(self) -> List[ZLLMStore]:
        return [s for _, s in self.router.items()
                if not getattr(s, "is_peer", False)]

    def _peer_store(self, key: str) -> ZLLMStore:
        """The local store that owns ``key`` (``repo_id/filename``) on this
        node — peer adopts always land on local storage."""
        single = self.router.single
        if single is not None and not getattr(single, "is_peer", False):
            return single
        repo_id, _, filename = key.rpartition("/")
        s = self.router.store(self.router.locate(repo_id, filename or key))
        if not getattr(s, "is_peer", False):
            return s
        return self._local_stores()[0]  # placement named a remote replica

    def _peer_snapshot_sync(self) -> Dict:
        """Build the full replication snapshot (runs on the executor):
        per-key index records sans local paths, the tombstone union, and
        the container version graph (nbytes / quarantined / dedup edges) —
        everything a remote anti-entropy pass needs to diff without
        touching container bytes. ``digest`` summarizes the whole snapshot
        so equal replicas can short-circuit on one string compare."""
        keys: Dict[str, Dict] = {}
        tombs: Dict[str, List] = {}
        versions: Dict[str, Dict] = {}
        bases: set = set()
        read_gen = 0
        for s in self._local_stores():
            for k, rec in s.file_index.items():
                keys[k] = {a: b for a, b in rec.items() if a != "path"}
            for k, (g, ts) in s.lifecycle.tombstones.items():
                cur = tombs.get(k)
                if cur is None or (g, ts) > (cur[0], cur[1]):
                    tombs[k] = [int(g), float(ts)]
            edges = s.lifecycle.edges
            for vid, v in s.lifecycle.versions.items():
                versions[vid] = {"nbytes": v.nbytes,
                                 "quarantined": bool(v.quarantined),
                                 "edges": sorted(edges.get(vid, ()))}
            bases.update(s.base_paths.keys())
            read_gen = max(read_gen, s.read_gen)
        payload = {"keys": keys, "tombstones": tombs, "versions": versions,
                   "base_paths": sorted(bases), "read_gen": read_gen}
        payload["digest"] = hashlib.sha256(
            json.dumps(payload, sort_keys=True).encode()).hexdigest()
        return payload

    async def _peer_index_digest(self, writer, req: _Request) -> None:
        snap = await asyncio.get_running_loop().run_in_executor(
            self.engine._pool, self._peer_snapshot_sync)
        await self._respond(writer, 200, snap, keep=req.keep)

    async def _peer_container(self, writer, req: _Request, vid: str,
                              qs: Dict[str, List[str]]) -> None:
        """Serve one container version's verbatim bytes (``?digest=1`` for
        its sha256 only). Range requests resume a killed download; the
        ``x-zllm-sha256`` header always carries the FULL file's digest so
        the fetcher verifies the assembled result, not the fragment."""
        key, sep, gen_s = vid.rpartition("@g")
        if not sep or not gen_s.isdigit():
            await self._respond(writer, 400,
                                {"error": f"bad container version id {vid!r} "
                                 "(want <key>@g<N>)"}, keep=req.keep)
            return
        gen = int(gen_s)
        store = self._peer_store(key)
        loop = asyncio.get_running_loop()
        allow_q = qs.get("allow_quarantined", ["0"])[0] not in ("0", "false", "")
        # KeyError -> 404 and RuntimeError("quarantined") -> 410 in _route
        digest = await loop.run_in_executor(
            self.engine._pool,
            lambda: store.container_digest(key, gen,
                                           allow_quarantined=allow_q))
        v = store.lifecycle.get(key, gen)
        if qs.get("digest", ["0"])[0] not in ("0", "false", ""):
            await self._respond(writer, 200,
                                {"sha256": digest, "nbytes": v.nbytes},
                                keep=req.keep)
            return
        with open(v.path, "rb") as f:  # immutable: safe to slurp + serve
            data = await loop.run_in_executor(None, f.read)
        await self._respond_ranged(writer, req, data,
                                   [("x-zllm-sha256", digest)])

    async def _read_json_body(self, writer, req: _Request) -> Optional[Dict]:
        """Read a bounded JSON control-plane body; answers the error
        response itself and returns None when the body is unusable."""
        te = req.headers.get("transfer-encoding", "").lower()
        try:
            length = int(req.headers.get("content-length", "0"))
        except ValueError:
            length = -1
        if "chunked" in te or length <= 0 or length > _MAX_JSON_BODY:
            req.keep = False
            await self._respond(writer, 411,
                                {"error": "JSON body with content-length "
                                 f"<= {_MAX_JSON_BODY} required"}, keep=False)
            return None
        body = await asyncio.wait_for(req.reader.readexactly(length),
                                      timeout=60)
        try:
            return json.loads(body)
        except ValueError:
            await self._respond(writer, 400, {"error": "malformed JSON body"},
                                keep=req.keep)
            return None

    async def _peer_adopt(self, writer, req: _Request,
                          qs: Dict[str, List[str]]) -> None:
        """Adopt shipped replica state. Three kinds:

        - ``kind=container`` (default): a resumable byte upload. The body
          appends to ``<spool>/adopt-<vid>.part`` at the offset declared in
          ``x-zllm-offset`` — a mismatch answers ``409 {"offset": N}`` so a
          killed transfer re-syncs instead of restarting; ``?stat=1`` asks
          for the current offset without sending bytes. Once the declared
          ``total`` is present the bytes are sha256-verified and adopted
          via the store's temp+rename ``adopt_container``; the ``.part``
          stage is then deleted (fsck sweeps any crash leftovers).
        - ``kind=restore``: same upload discipline, but the bytes heal a
          *quarantined* version via ``restore_version``.
        - ``kind=record``: JSON ``{"key":..., "rec":...}`` adopted via
          ``adopt_index_record``; a missing ref closure answers 409 (ship
          the dependency containers first).
        """
        kind = qs.get("kind", ["container"])[0]
        loop = asyncio.get_running_loop()
        if kind == "record":
            spec = await self._read_json_body(writer, req)
            if spec is None:
                return
            try:
                key, rec = spec["key"], dict(spec["rec"])
            except (KeyError, TypeError):
                await self._respond(writer, 400,
                                    {"error": 'body must be {"key": ..., '
                                     '"rec": {...}}'}, keep=req.keep)
                return
            store = self._peer_store(key)
            try:
                await loop.run_in_executor(
                    self.engine._pool,
                    lambda: store.adopt_index_record(key, rec))
            except KeyError as e:  # ref target not live here yet
                await self._respond(writer, 409, {"error": str(e)},
                                    keep=req.keep)
                return
            await loop.run_in_executor(self.engine._pool, store.save_index)
            await self._respond(writer, 200, {"adopted": True}, keep=req.keep)
            return
        if kind not in ("container", "restore"):
            await self._drain_body(req)
            await self._respond(writer, 400,
                                {"error": f"unknown adopt kind {kind!r}"},
                                keep=req.keep)
            return
        key = qs.get("key", [None])[0]
        sha = qs.get("sha256", [""])[0]
        try:
            gen = int(qs.get("gen", ["-1"])[0])
            total = int(qs.get("total", ["-1"])[0])
        except ValueError:
            gen = total = -1
        if not key or gen < 0 or total < 0 or not sha:
            req.keep = False
            await self._respond(writer, 400,
                                {"error": "adopt needs key, gen, sha256 and "
                                 "total query params"}, keep=False)
            return
        store = self._peer_store(key)
        vid = make_vid(key, gen)
        part = os.path.join(store.spool_dir(),
                            "adopt-" + vid.replace("/", "__") + TMP_SUFFIX)
        have = os.path.getsize(part) if os.path.exists(part) else 0
        already = store.lifecycle.exists(key, gen) and not store.lifecycle.get(
            key, gen).quarantined
        if qs.get("stat", ["0"])[0] not in ("0", "false", ""):
            await self._drain_body(req)
            await self._respond(writer, 200,
                                {"offset": have, "adopted": already},
                                keep=req.keep)
            return
        if already and kind == "container":
            # idempotent short-circuit: the version is live here already
            await self._drain_body(req)
            try:
                os.remove(part)
            except OSError:
                pass
            await self._respond(writer, 200, {"adopted": False}, keep=req.keep)
            return
        try:
            offset = int(req.headers.get("x-zllm-offset", "0"))
            length = int(req.headers["content-length"])
        except (KeyError, ValueError):
            req.keep = False
            await self._respond(writer, 411,
                                {"error": "content-length and x-zllm-offset "
                                 "required"}, keep=False)
            return
        if offset != have or offset + length != total:
            # stale offset (e.g. the .part outlived a crashed transfer):
            # tell the shipper where to resume; its body goes unread, so
            # this connection cannot be reused
            req.keep = False
            await self._respond(writer, 409, {"offset": have}, keep=False)
            return
        received = 0
        with open(part, "ab") as f:
            while received < length:
                chunk = await asyncio.wait_for(
                    req.reader.read(min(_UPLOAD_CHUNK, length - received)),
                    timeout=120)
                if not chunk:
                    # killed mid-ship: keep the .part for resume, drop conn
                    raise ConnectionError("peer client closed mid-ship")
                await loop.run_in_executor(None, f.write, chunk)
                received += len(chunk)
            f.flush()
            os.fsync(f.fileno())
        if kind == "restore":
            try:
                ok = await loop.run_in_executor(
                    self.engine._pool,
                    lambda: store.restore_version(key, gen, part,
                                                  expected_sha256=sha))
            except ValueError as e:  # sha mismatch: corrupt ship, restart
                try:
                    os.remove(part)
                except OSError:
                    pass
                await self._respond(writer, 400, {"error": str(e)},
                                    keep=req.keep)
                return
            if not ok:  # not quarantined: nothing to heal, stage is debris
                try:
                    os.remove(part)
                except OSError:
                    pass
            await self._respond(writer, 200, {"restored": bool(ok)},
                                keep=req.keep)
            return
        try:
            adopted = await loop.run_in_executor(
                self.engine._pool,
                lambda: store.adopt_container(key, gen, part,
                                              expected_sha256=sha))
        except ValueError as e:  # sha mismatch: corrupt ship, restart clean
            try:
                os.remove(part)
            except OSError:
                pass
            await self._respond(writer, 400, {"error": str(e)}, keep=req.keep)
            return
        # crash window under test: the version is live in memory + on disk
        # but the index is not yet persisted — recovery is reopen + fsck +
        # the next sweep's idempotent re-ship
        store._fault("peer.adopt_pre_persist")
        await loop.run_in_executor(self.engine._pool, store.save_index)
        try:
            os.remove(part)  # adopt copied the bytes: the stage is debris
        except OSError:
            pass
        await self._respond(writer, 200, {"adopted": bool(adopted)},
                            keep=req.keep)

    async def _peer_tombstones(self, writer, req: _Request) -> None:
        spec = await self._read_json_body(writer, req)
        if spec is None:
            return
        batch = spec.get("tombstones")
        if not isinstance(batch, list):
            await self._respond(writer, 400,
                                {"error": 'body must be {"tombstones": '
                                 '[[key, gen, ts], ...]}'}, keep=req.keep)
            return

        def apply() -> int:
            n = 0
            touched = []
            for key, gen, ts in batch:
                store = self._peer_store(key)
                if store.apply_tombstone(str(key), int(gen), float(ts)):
                    n += 1
                if store not in touched:
                    touched.append(store)
            for store in touched:
                store.save_index()
            return n

        applied = await asyncio.get_running_loop().run_in_executor(
            self.engine._pool, apply)
        await self._respond(writer, 200,
                            {"applied": applied, "batch": len(batch)},
                            keep=req.keep)

    # -- response plumbing ----------------------------------------------------
    async def _respond(self, writer, status: int, obj: Dict, *,
                       keep: bool = False,
                       extra: Optional[List[Tuple[str, str]]] = None) -> None:
        body = (json.dumps(obj) + "\n").encode()
        await self._write(writer, status, body, "application/json",
                          extra or [], keep)

    @classmethod
    def _head(cls, status: int, length: int, ctype: str, extra,
              keep: bool) -> bytes:
        head = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
                f"content-type: {ctype}",
                f"content-length: {length}",
                "accept-ranges: bytes",
                f"connection: {'keep-alive' if keep else 'close'}"]
        head += [f"{k}: {v}" for k, v in extra]
        return ("\r\n".join(head) + "\r\n\r\n").encode()

    @classmethod
    async def _write(cls, writer, status: int, body, ctype: str, extra,
                     keep: bool) -> None:
        writer.write(cls._head(status, len(body), ctype, extra, keep))
        writer.write(body)
        await writer.drain()


class ServerThread:
    """Run a :class:`StoreServer` on a private event loop in a daemon
    thread — the harness for synchronous callers (tests, benches, soak).
    ``store`` may be a single :class:`ZLLMStore` or a
    :class:`StoreRouter`. Usable as a context manager; ``host``/``port``
    are set after start."""

    def __init__(self, store, **server_kw):
        self._store = store
        self._kw = server_kw
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self.server: Optional[StoreServer] = None
        self.host: Optional[str] = None
        self.port: Optional[int] = None

    def start(self) -> "ServerThread":
        started = threading.Event()
        fail: list = []

        def run():
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)
            try:
                self.server = StoreServer(self._store, **self._kw)
                host_port = loop.run_until_complete(self.server.start())
            except BaseException as e:  # surface startup failures (e.g.
                # EADDRINUSE) to the caller; self._loop stays None so a
                # defensive stop() returns immediately instead of waiting on
                # a loop that will never run
                fail.append(e)
                self.server = None
                loop.close()
                started.set()
                return
            self._loop = loop
            self.host, self.port = host_port
            started.set()
            try:
                loop.run_forever()
            finally:
                loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="zllm-server")
        self._thread.start()
        started.wait(timeout=60)
        if fail:
            raise fail[0]
        assert self.port is not None, "server failed to start within 60s"
        return self

    def submit(self, coro):
        """Schedule a coroutine on the server loop; returns a concurrent
        Future (e.g. ``submit(engine.run_gc()).result()``)."""
        return asyncio.run_coroutine_threadsafe(coro, self._loop)

    def stop(self) -> None:
        if self._loop is None:
            return
        if self.server is not None:
            asyncio.run_coroutine_threadsafe(self.server.aclose(),
                                             self._loop).result(timeout=60)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=60)
        self._loop = None

    def __enter__(self) -> "ServerThread":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Serve zLLM store root(s) over HTTP (asyncio, stdlib-only)")
    ap.add_argument("--root", required=True, action="append",
                    help="store root directory (repeat for a sharded "
                         "multi-root node; repos are consistent-hashed "
                         "across roots)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8421)
    ap.add_argument("--store-workers", type=int, default=2,
                    help="ZLLMStore decode pool size (per root)")
    ap.add_argument("--serve-workers", type=int, default=8,
                    help="concurrent retrieval executor size (per root)")
    ap.add_argument("--cache-mb", type=int, default=128)
    ap.add_argument("--spill-mb", type=int, default=None,
                    help="decoded-spill disk budget per root, MB "
                         "(default: 4x --cache-mb; 0 disables the disk "
                         "tier)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip sha256 verification of responses")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica group size per repo (clamped to the "
                         "number of roots); 1 = shard-only placement")
    ap.add_argument("--write-quorum", type=int, default=None,
                    help="write acks required before a PUT succeeds "
                         "(default: majority of --replicas)")
    ap.add_argument("--peer", action="append", default=[],
                    help="remote peer URL (host:port; repeatable) mounted "
                         "as a replica root behind the /peer/* protocol — "
                         "replica groups then span server processes")
    args = ap.parse_args(argv)

    router = StoreRouter.open_roots(args.root, workers=args.store_workers,
                                    replicas=args.replicas,
                                    write_quorum=args.write_quorum,
                                    peers=args.peer)
    for name, store in router.items():
        if not store.file_index:
            print(f"store_server: no index under {store.root} "
                  f"(root {name} starts empty)", flush=True)

    async def amain():
        server = StoreServer(router, args.host, args.port,
                             max_concurrency=args.serve_workers,
                             cache_bytes=args.cache_mb << 20,
                             spill_bytes=(None if args.spill_mb is None
                                          else args.spill_mb << 20),
                             verify=not args.no_verify)
        host, port = await server.start()
        roots = ", ".join(f"{n}={s.root}" for n, s in router.items())
        print(f"store_server: serving {roots} on http://{host}:{port}",
              flush=True)
        await server.serve_forever()

    try:
        asyncio.run(amain())
    except KeyboardInterrupt:
        pass
    finally:
        router.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
