"""Single-flight deduplication for concurrent async work (stdlib asyncio).

When N concurrent requests ask for the same expensive computation (decoding
the same container record, reconstructing the same file), exactly one —
the *leader* — runs it; the other N-1 await the leader's future and share
the result. This is the asyncio analogue of Go's ``singleflight`` package,
and the piece that keeps the retrieval server's worker pool from decoding
one hot checkpoint eight times side by side.

Keys must already encode *everything* the result depends on. The store
server keys flights by ``(store.read_gen, kind, repo, file[, tensor])`` —
the read generation rolls over on every ingest/delete/gc, so a request
issued after a mutation can never coalesce onto a stale in-flight decode
(see the read-gate notes in ``repro.core.pipeline``).
"""

from __future__ import annotations

import asyncio
from typing import Any, Awaitable, Callable, Dict, Hashable

__all__ = ["SingleFlight"]


class SingleFlight:
    """Coalesce concurrent async calls per key. Event-loop-confined: call
    :meth:`run` only from coroutines on one loop (no internal locking is
    needed precisely because of that confinement)."""

    def __init__(self):
        self._inflight: Dict[Hashable, asyncio.Future] = {}
        self.leaders = 0   # flights actually executed
        self.joined = 0    # calls that shared another call's flight

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    async def run(self, key: Hashable, thunk: Callable[[], Awaitable[Any]]) -> Any:
        """Return ``await thunk()``, sharing one execution among all
        concurrent callers with the same ``key``.

        The leader's outcome — result or exception — propagates to every
        joiner. A joiner being cancelled does not cancel the shared flight
        (the future is shielded); a cancelled *leader* cancels the flight
        for everyone, which is the honest outcome since its work stopped.
        """
        existing = self._inflight.get(key)
        if existing is not None:
            self.joined += 1
            return await asyncio.shield(existing)

        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        self._inflight[key] = fut
        self.leaders += 1
        try:
            result = await thunk()
        except BaseException as e:
            self._inflight.pop(key, None)
            if not fut.cancelled():
                fut.set_exception(e)
                fut.exception()  # mark retrieved: no-joiner flights must not
                # warn "exception was never retrieved" at GC time
            raise
        else:
            self._inflight.pop(key, None)
            if not fut.cancelled():
                fut.set_result(result)
            return result

    def stats(self) -> Dict[str, int]:
        return {"leaders": self.leaders, "joined": self.joined,
                "inflight": self.inflight}
