"""Single-flight deduplication + the two-tier decoded-response cache.

When N concurrent requests ask for the same expensive computation (decoding
the same container record, reconstructing the same file), exactly one —
the *leader* — runs it; the other N-1 await the leader's future and share
the result. This is the asyncio analogue of Go's ``singleflight`` package,
and the piece that keeps the retrieval server's worker pool from decoding
one hot checkpoint eight times side by side.

Keys must already encode *everything* the result depends on. The store
server keys flights by ``(store.read_gen, entity_tag, kind, repo, file[,
tensor])`` — the read generation rolls over on every ingest/delete/gc, so
a request issued after a mutation can never coalesce onto a stale
in-flight decode (see the read-gate notes in ``repro.core.pipeline``).

:class:`TieredResponseCache` is what finished flights land in: a
byte-budgeted RAM LRU over an mmap-read disk spill directory (the store
root's ``.decoded/``). Entries are keyed by ``(object key, strong
validator)`` — the same ``key@gN`` entity tag conditional HTTP GETs
revalidate against — so hot tensors evicted from RAM stop re-paying
entropy decode (they promote back from disk), and gc/compact invalidation
stays trivial: a re-registered key gets a new validator, the old entry
simply stops being addressed and is purged on the next observed mutation.
"""

from __future__ import annotations

import asyncio
import hashlib
import json
import mmap
import os
import struct
from collections import OrderedDict
from typing import Any, Awaitable, Callable, Dict, Hashable, Optional, Tuple

from repro.core.pipeline import _LRUCache

__all__ = ["SingleFlight", "TieredResponseCache"]


class SingleFlight:
    """Coalesce concurrent async calls per key. Event-loop-confined: call
    :meth:`run` only from coroutines on one loop (no internal locking is
    needed precisely because of that confinement)."""

    def __init__(self):
        self._inflight: Dict[Hashable, asyncio.Future] = {}
        self.leaders = 0   # flights actually executed
        self.joined = 0    # calls that shared another call's flight

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    async def run(self, key: Hashable, thunk: Callable[[], Awaitable[Any]]) -> Any:
        """Return ``await thunk()``, sharing one execution among all
        concurrent callers with the same ``key``.

        The leader's outcome — result or exception — propagates to every
        joiner. A joiner being cancelled does not cancel the shared flight
        (the future is shielded); a cancelled *leader* cancels the flight
        for everyone, which is the honest outcome since its work stopped.
        """
        existing = self._inflight.get(key)
        if existing is not None:
            self.joined += 1
            return await asyncio.shield(existing)

        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        self._inflight[key] = fut
        self.leaders += 1
        try:
            result = await thunk()
        except BaseException as e:
            self._inflight.pop(key, None)
            if not fut.cancelled():
                fut.set_exception(e)
                fut.exception()  # mark retrieved: no-joiner flights must not
                # warn "exception was never retrieved" at GC time
            raise
        else:
            self._inflight.pop(key, None)
            if not fut.cancelled():
                fut.set_result(result)
            return result

    def stats(self) -> Dict[str, int]:
        return {"leaders": self.leaders, "joined": self.joined,
                "inflight": self.inflight}


_SPILL_SUFFIX = ".dec"
_SPILL_TMP = ".part"   # same crash-debris contract as container writes


class TieredResponseCache:
    """Decoded-response cache with a RAM tier and a disk spill tier.

    * **RAM tier** — a byte-budgeted LRU of finished decode results
      (``bytes`` or ``(bytes, meta)`` tuples), keyed by ``(objkey,
      validator)`` where ``objkey`` is the engine's object coordinate
      (``("file", repo, file)`` / ``("tensor", repo, file, name)``) and
      ``validator`` the store's strong entity tag for that key (the
      ``key@gN`` form served as the HTTP ETag).
    * **Disk tier** — RAM evictions spill to ``spill_dir`` (the store
      root's ``.decoded/``) with the container write discipline
      (temp ``.part`` + atomic rename; crash debris is cleaned by the
      fsck orphan scan). A RAM miss that hits disk *promotes*: the
      payload is mmap-read back into the RAM tier and the spill file is
      dropped — an entry lives in exactly one tier.

    Validator keying makes lifecycle invalidation trivial: generations
    are immutable, so an entry can only go stale by its key being
    re-registered / deleted — which changes the key's current validator.
    :meth:`purge` drops every entry whose validator is no longer current
    (called when the engine observes a ``read_gen`` change), so dead
    generations never squat on either byte budget.

    Loop-confined like the engine that owns it: no internal locking.
    The constructor wipes ``spill_dir`` — spill files are cache state of
    one engine process, not durable data.
    """

    def __init__(self, spill_dir: Optional[str] = None, *,
                 max_bytes: int = 128 << 20,
                 spill_max_bytes: Optional[int] = None,
                 max_items: int = 1024):
        self._ram = _LRUCache(max_items=max_items, max_bytes=max_bytes,
                              on_evict=self._spill)
        self.spill_dir = spill_dir
        self.spill_max_bytes = (spill_max_bytes if spill_max_bytes is not None
                                else 4 * max_bytes)
        # spill index: fname -> (file bytes, objkey, validator); insertion
        # order is the disk tier's LRU order
        self._files: "OrderedDict[str, Tuple[int, Tuple, str]]" = OrderedDict()
        self._spill_bytes = 0
        self.ram_hits = self.disk_hits = self.misses = 0
        self.spills = self.promotions = self.purged = 0
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
            for fn in os.listdir(spill_dir):  # cold start: previous
                # process's spill files (and any crash debris) are stale
                if fn.endswith((_SPILL_SUFFIX, _SPILL_TMP)):
                    try:
                        os.remove(os.path.join(spill_dir, fn))
                    except OSError:
                        pass

    # -- public surface -------------------------------------------------
    def get(self, objkey: Tuple, validator: str) -> Any:
        ent = self._ram.get((objkey, validator))
        if ent is not None:
            self.ram_hits += 1
            return ent[2]
        value_nbytes = self._load_spill(objkey, validator)
        if value_nbytes is not None:
            value, nbytes = value_nbytes
            self.disk_hits += 1
            self.promotions += 1
            # promote: disk -> RAM (may cascade other entries to disk)
            self._ram.put((objkey, validator), (objkey, validator, value),
                          nbytes)
            return value
        self.misses += 1
        return None

    def put(self, objkey: Tuple, validator: str, value: Any,
            nbytes: int) -> None:
        self._ram.put((objkey, validator), (objkey, validator, value),
                      nbytes)

    def purge(self, is_current: Callable[[Tuple, str], bool]) -> int:
        """Drop every entry (both tiers) whose ``(objkey, validator)``
        fails ``is_current`` — entries of re-registered / deleted keys.
        Dead RAM entries are discarded WITHOUT spilling (that would just
        move the squatting to disk). Returns the number purged."""
        n = 0
        for k in self._ram.keys():
            if not is_current(*k):
                self._ram.discard(k)
                n += 1
        for fname in list(self._files):
            _, objkey, validator = self._files[fname]
            if not is_current(objkey, validator):
                self._drop_spill(fname)
                n += 1
        self.purged += n
        return n

    def clear(self) -> None:
        for k in self._ram.keys():
            self._ram.discard(k)
        for fname in list(self._files):
            self._drop_spill(fname)

    @property
    def ram_bytes(self) -> int:
        return self._ram.nbytes

    @property
    def spill_bytes(self) -> int:
        return self._spill_bytes

    def __len__(self) -> int:
        return len(self._ram) + len(self._files)

    def stats(self) -> Dict[str, int]:
        return {"items": len(self._ram), "spilled_items": len(self._files),
                "hits": self.ram_hits, "disk_hits": self.disk_hits,
                "misses": self.misses, "spills": self.spills,
                "promotions": self.promotions, "purged": self.purged,
                "ram_bytes": self.ram_bytes, "spill_bytes": self._spill_bytes}

    # -- spill tier -----------------------------------------------------
    @staticmethod
    def _fname(objkey: Tuple, validator: str) -> str:
        h = hashlib.sha256(repr((objkey, validator)).encode()).hexdigest()
        return h[:32] + _SPILL_SUFFIX

    def _spill(self, ent: Tuple) -> None:
        """RAM-eviction hook: serialize the entry into the spill dir
        (4-byte header length, JSON header, raw payload). Best-effort —
        a full disk degrades to a plain LRU, never an error."""
        if self.spill_dir is None:
            return
        objkey, validator, value = ent
        payload, meta = (value if isinstance(value, tuple) and len(value) == 2
                         else (value, None))
        if not isinstance(payload, (bytes, bytearray, memoryview)):
            return
        header = json.dumps({"k": list(objkey), "v": validator, "meta": meta,
                             "n": len(payload)}).encode()
        fname = self._fname(objkey, validator)
        path = os.path.join(self.spill_dir, fname)
        tmp = path + _SPILL_TMP
        try:
            with open(tmp, "wb") as f:
                f.write(struct.pack(">I", len(header)))
                f.write(header)
                f.write(payload)
            os.replace(tmp, path)
        except (OSError, TypeError, ValueError):
            try:
                os.remove(tmp)
            except OSError:
                pass
            return
        nbytes = 4 + len(header) + len(payload)
        old = self._files.pop(fname, None)
        if old is not None:
            self._spill_bytes -= old[0]
        self._files[fname] = (nbytes, objkey, validator)
        self._spill_bytes += nbytes
        self.spills += 1
        while self._spill_bytes > self.spill_max_bytes and len(self._files) > 1:
            self._drop_spill(next(iter(self._files)))

    def _drop_spill(self, fname: str) -> None:
        ent = self._files.pop(fname, None)
        if ent is None:
            return
        self._spill_bytes -= ent[0]
        if self.spill_dir is not None:
            try:
                os.remove(os.path.join(self.spill_dir, fname))
            except OSError:
                pass

    def _load_spill(self, objkey: Tuple,
                    validator: str) -> Optional[Tuple[Any, int]]:
        """(value, payload nbytes) read back from the spill tier, or
        ``None``. The spill file is consumed (promotion moves the entry);
        any irregularity — deleted file, torn write, hash-name collision
        — degrades to a miss."""
        fname = self._fname(objkey, validator)
        if self.spill_dir is None or fname not in self._files:
            return None
        path = os.path.join(self.spill_dir, fname)
        try:
            with open(path, "rb") as f:
                with mmap.mmap(f.fileno(), 0,
                               access=mmap.ACCESS_READ) as mm:
                    (hlen,) = struct.unpack(">I", mm[:4])
                    hdr = json.loads(bytes(mm[4:4 + hlen]).decode())
                    if (tuple(hdr["k"]) != tuple(objkey)
                            or hdr["v"] != validator):
                        self._drop_spill(fname)
                        return None
                    n = int(hdr["n"])
                    payload = bytes(mm[4 + hlen:4 + hlen + n])
                    if len(payload) != n:
                        self._drop_spill(fname)
                        return None
        except (OSError, ValueError, KeyError, struct.error):
            self._drop_spill(fname)
            return None
        self._drop_spill(fname)
        meta = hdr.get("meta")
        value = payload if meta is None else (payload, meta)
        return value, n
