"""Multi-store routing: one serving frontend over N ``ZLLMStore`` roots.

A hub node outgrows a single store root long before it outgrows a single
machine: separate NVMe volumes, per-tenant roots, or simply more index than
one process cares to keep hot. ``StoreRouter`` spreads *repos* across N
roots with **rendezvous (highest-random-weight) consistent hashing** —
every repo deterministically owns one root, adding a root only moves
~1/(N+1) of the keyspace, and no ring state needs persisting — while the
HTTP layer stays oblivious: it asks the router which store serves a repo
and proceeds exactly as in the single-root case.

Placement vs. location: ``place()`` is the pure hash (where a new repo
*goes*); ``locate()`` prefers a root that already *has* the key (so a
router can be put in front of pre-existing stores whose contents predate
the hash placement) and falls back to ``place()`` for keys nobody holds.
Writes route through ``locate()`` too — a re-registration must land on the
root that holds the repo's earlier generations, or the dedup/BitX chain
would be severed.

Stats keep the **flat single-root shape** when there is one root (the
``server_smoke`` back-compat contract) and nest per-root sections plus
cross-root aggregates under N roots. Admin operations (gc / compact /
fsck) fan out to every root, or to one root via its name.

The router owns no asyncio state — it is shared safely between the event
loop and worker threads; per-root ``RetrievalEngine`` construction stays in
the server (engines are loop-confined).
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.core.pipeline import ZLLMStore

__all__ = ["StoreRouter"]

# store.summary() keys that aggregate by plain summation across roots
_SUM_KEYS = ("n_files", "raw_bytes", "stored_bytes", "file_dedup_hits",
             "near_dup_hits")
_SUM_LIFECYCLE_KEYS = ("versions", "live_bytes", "superseded_bytes",
                       "reclaimed_bytes", "collected", "gc_runs",
                       "deleted_files", "compact_runs",
                       "compaction_reclaimed_bytes")


class StoreRouter:
    """Consistent-hash placement of repos over named ``ZLLMStore`` roots.

    ``stores`` is a mapping ``name -> ZLLMStore`` (ordered; names appear in
    stats and in ``?root=`` admin selectors), or a plain sequence of stores
    (auto-named ``r0``, ``r1``, ...). A single-store router is the identity
    — the server wraps every deployment in one so the two topologies share
    a code path.
    """

    def __init__(self, stores: Union[Dict[str, ZLLMStore],
                                     Sequence[ZLLMStore], ZLLMStore]):
        if isinstance(stores, ZLLMStore):
            stores = [stores]
        if not isinstance(stores, dict):
            stores = OrderedDict((f"r{i}", s) for i, s in enumerate(stores))
        if not stores:
            raise ValueError("StoreRouter needs at least one store")
        self.roots: "OrderedDict[str, ZLLMStore]" = OrderedDict(stores)
        # repo -> root decisions for writes whose ingest job has not
        # registered in file_index yet: a second PUT for the same new repo
        # arriving inside that window must land on the SAME root, or the
        # repo splits across roots (severing its dedup/BitX chain).
        # Bounded; stale entries are harmless — membership wins once the
        # ingest lands, and a pending entry names that same root anyway.
        self._pending_places: "OrderedDict[str, str]" = OrderedDict()

    # -- topology ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self.roots)

    def names(self) -> List[str]:
        return list(self.roots)

    def items(self) -> Iterable[Tuple[str, ZLLMStore]]:
        return self.roots.items()

    def store(self, name: str) -> ZLLMStore:
        return self.roots[name]

    @property
    def single(self) -> Optional[ZLLMStore]:
        """The lone store of a single-root router, else None."""
        return next(iter(self.roots.values())) if len(self.roots) == 1 else None

    # -- placement ----------------------------------------------------------
    def place(self, repo_id: str) -> str:
        """Root name owning ``repo_id`` under rendezvous hashing: the root
        whose ``sha256(name | repo_id)`` scores highest. Deterministic,
        state-free, and minimally disruptive when roots are added."""
        return max(self.roots,
                   key=lambda n: hashlib.sha256(
                       f"{n}|{repo_id}".encode()).digest())

    def _membership_root(self, repo_id: str, filename: str) -> Optional[str]:
        """Root already holding ``repo_id`` — by exact key, by another
        file of the same repo (repo-cohesion: one repo, one root), or by
        an in-flight write decision whose ingest job has not registered
        yet. ``list()`` snapshots the keys under the GIL — the background
        ingest worker inserts into ``file_index`` concurrently, and
        iterating the live dict view would race it ("dictionary changed
        size during iteration")."""
        key = f"{repo_id}/{filename}"
        for name, store in self.roots.items():
            if key in store.file_index:  # atomic membership probe
                return name
        prefix = repo_id + "/"
        for name, store in self.roots.items():
            if any(k.startswith(prefix) for k in list(store.file_index)):
                return name
        return self._pending_places.get(repo_id)

    def locate(self, repo_id: str, filename: str = "model.safetensors") -> str:
        """Root name *serving* ``repo_id/filename``: a root that already
        holds the repo (or has a write for it in flight) wins — pre-seeded
        stores, pre-resize placements, not-yet-registered ingest jobs;
        otherwise the hash placement. Reads and writes both route here, so
        re-registrations land beside the generations they supersede."""
        return self._membership_root(repo_id, filename) or self.place(repo_id)

    def store_for(self, repo_id: str,
                  filename: str = "model.safetensors") -> ZLLMStore:
        return self.roots[self.locate(repo_id, filename)]

    def locate_for_write(self, repo_id: str,
                         filename: str = "model.safetensors",
                         base: Optional[str] = None) -> str:
        """Placement for an incoming write. Like :meth:`locate`, but a NEW
        repo that declares a BitX base co-locates with the root serving
        that base — dedup and delta domains are per-root, so scattering a
        family across roots would store every fine-tune standalone. The
        decision is memoized in ``_pending_places`` so a second write for
        the same repo arriving before the first ingest job registers
        still routes to the same root."""
        root = self._membership_root(repo_id, filename)
        if root is None and base:
            bkey = f"{base}/model.safetensors"
            for name, store in self.roots.items():
                if bkey in store.file_index or base in store.base_paths:
                    root = name
                    break
        if root is None:
            root = self.place(repo_id)
        self._pending_places[repo_id] = root
        while len(self._pending_places) > 1024:
            self._pending_places.popitem(last=False)
        return root

    # -- aggregate stats ------------------------------------------------------
    def summary(self) -> Dict:
        """Aggregated ``store.summary()``. Single root: the flat summary,
        unchanged (back-compat for ``server_smoke`` and /stats consumers).
        N roots: summable counters aggregated at the top plus the full
        per-root summaries under ``roots``."""
        single = self.single
        if single is not None:
            return single.summary()
        per_root = {name: store.summary() for name, store in self.roots.items()}
        agg: Dict = {k: sum(s[k] for s in per_root.values()) for k in _SUM_KEYS}
        agg["reduction_ratio"] = round(
            1.0 - agg["stored_bytes"] / agg["raw_bytes"], 4
        ) if agg["raw_bytes"] else 0.0
        agg["lifecycle"] = {k: sum(s["lifecycle"][k] for s in per_root.values())
                            for k in _SUM_LIFECYCLE_KEYS}
        agg["lifecycle"]["gc_max_pause_ms"] = max(
            s["lifecycle"]["gc_max_pause_ms"] for s in per_root.values())
        agg["read_gen"] = {name: s["read_gen"] for name, s in per_root.items()}
        agg["n_roots"] = len(per_root)
        agg["roots"] = per_root
        return agg

    def ingest_jobs(self, limit: int = 64) -> List[Dict]:
        """Recent spooled-ingest jobs across every root (each row carries
        its ``root``), newest first."""
        rows: List[Dict] = []
        for name, store in self.roots.items():
            for j in store.ingest_jobs(limit):
                j["root"] = name
                rows.append(j)
        rows.sort(key=lambda j: j["enqueued_at"], reverse=True)
        return rows[:limit]

    def ingest_job(self, job_id: str) -> Optional[Dict]:
        """Look a job id up across roots (ids are store-local)."""
        for name, store in self.roots.items():
            j = store.ingest_job(job_id)
            if j is not None:
                j["root"] = name
                return j
        return None

    # -- admin fan-out ------------------------------------------------------
    def _selected(self, root: Optional[str]) -> List[Tuple[str, ZLLMStore]]:
        if root is None:
            return list(self.roots.items())
        if root not in self.roots:
            raise KeyError(f"unknown root {root!r} "
                           f"(have: {', '.join(self.roots)})")
        return [(root, self.roots[root])]

    def fanout_gc(self, root: Optional[str] = None, *, incremental: bool = False,
                  max_pause_ms: float = 50.0) -> Dict:
        reports = {name: store.gc(incremental=incremental,
                                  max_pause_ms=max_pause_ms)
                   for name, store in self._selected(root)}
        return self._flat_or_nested(reports, ("collected", "reclaimed_bytes"))

    def fanout_compact(self, root: Optional[str] = None) -> Dict:
        reports = {name: store.compact()
                   for name, store in self._selected(root)}
        return self._flat_or_nested(
            reports, ("retired_versions", "moved_records",
                      "net_reclaimed_bytes"))

    def fanout_fsck(self, root: Optional[str] = None, *, repair: bool = False,
                    spot_check: Optional[int] = 4) -> Dict:
        reports = {}
        for name, store in self._selected(root):
            rep = store.fsck(repair=repair, spot_check=spot_check)
            reports[name] = {"ok": rep.ok, "summary": rep.summary(),
                             "orphans": len(rep.orphans),
                             "quarantined": len(rep.quarantined)}
        if len(reports) == 1 and len(self.roots) == 1:
            return next(iter(reports.values()))
        out = {"roots": reports, "ok": all(r["ok"] for r in reports.values())}
        return out

    def _flat_or_nested(self, reports: Dict[str, Dict],
                        sum_keys: Tuple[str, ...]) -> Dict:
        """One root selected on a single-root router → the flat report
        (back-compat); otherwise per-root reports plus summed headline
        numbers."""
        if len(reports) == 1 and len(self.roots) == 1:
            return next(iter(reports.values()))
        out: Dict = {k: sum(r.get(k, 0) for r in reports.values())
                     for k in sum_keys}
        out["roots"] = reports
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Close every store exactly once (dict values may repeat when the
        same store is mounted under two names)."""
        for store in {id(s): s for s in self.roots.values()}.values():
            store.close()

    @staticmethod
    def open_roots(paths: Sequence[str], *, workers: int = 2) -> "StoreRouter":
        """CLI helper: open one store per path (index loaded when present),
        named ``r0..rN`` with the path recorded for display."""
        stores: "OrderedDict[str, ZLLMStore]" = OrderedDict()
        for i, path in enumerate(paths):
            store = ZLLMStore(path, workers=workers)
            store.load_index()
            stores[f"r{i}"] = store
        return StoreRouter(stores)
