"""Multi-store routing: one serving frontend over N ``ZLLMStore`` roots.

A hub node outgrows a single store root long before it outgrows a single
machine: separate NVMe volumes, per-tenant roots, or simply more index than
one process cares to keep hot. ``StoreRouter`` spreads *repos* across N
roots with **rendezvous (highest-random-weight) consistent hashing** —
every repo deterministically owns one root, adding a root only moves
~1/(N+1) of the keyspace, and no ring state needs persisting — while the
HTTP layer stays oblivious: it asks the router which store serves a repo
and proceeds exactly as in the single-root case.

Placement vs. location: ``place()`` is the pure hash (where a new repo
*goes*); ``locate()`` prefers a root that already *has* the key (so a
router can be put in front of pre-existing stores whose contents predate
the hash placement) and falls back to ``place()`` for keys nobody holds.
Writes route through ``locate()`` too — a re-registration must land on the
root that holds the repo's earlier generations, or the dedup/BitX chain
would be severed.

Stats keep the **flat single-root shape** when there is one root (the
``server_smoke`` back-compat contract) and nest per-root sections plus
cross-root aggregates under N roots. Admin operations (gc / compact /
fsck) fan out to every root, or to one root via its name.

**Replication** (``replicas=N``): the rendezvous hash's *ordered* candidate
list is the replica group — the top-N scoring roots hold copies of every
repo. Writes fan out to the whole group and acknowledge at a configurable
write quorum (W of N, retry + exponential backoff per root, asynchronous
repair of stragglers on the store's job worker); reads fail over down the
candidate list behind a health tracker (a failing root turns *suspect* and
is probed again after an exponentially growing backoff); an
**anti-entropy sweep** diffs the per-root ``(key, gen)`` indexes within
each group, applies delete tombstones, restores quarantined containers
from healthy same-generation copies (sha256-verified, swapped back in) and
re-ships missing generations with container bytes copied **verbatim** —
replica containers stay bit-identical. One caveat is inherent: per-root
``.compact/pool`` containers are local artifacts (roots compact
independently), so a quarantined pool version has no same-bytes donor;
anchored containers — everything a client can address — always do.

The router owns no asyncio state — it is shared safely between the event
loop and worker threads; per-root ``RetrievalEngine`` construction stays in
the server (engines are loop-confined).
"""

from __future__ import annotations

import hashlib
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple, Union

from repro.core.lifecycle import make_vid
from repro.core.pipeline import ZLLMStore

__all__ = ["StoreRouter", "RootDownError", "QuorumError",
           "REPLICATION_FAULT_POINTS"]

# Fault points the replication crash harness (tests/test_replication.py,
# tests/test_peer_replication.py) may kill the router at, via
# ``router.fault_hook`` — same contract as the store's COMPACT/GC fault
# points: no cleanup runs when the hook raises. The ``peer.*`` points fire
# on the wire protocol (``peer.ship_mid_body`` from the shipping client
# after the first body chunk, ``peer.adopt_pre_persist`` on the receiving
# server between adopt and index persist); ``hint.pre_drain_persist``
# fires after a hinted re-ship lands but before the hint log drops it.
REPLICATION_FAULT_POINTS = ("put.mid_fanout", "put.post_quorum",
                            "anti_entropy.mid_copy", "restore.mid_copy",
                            "peer.ship_mid_body", "peer.adopt_pre_persist",
                            "hint.pre_drain_persist")


class RootDownError(ConnectionError):
    """A replica root is down (health tracker) — writes/reads must not be
    attempted against it."""


class QuorumError(ConnectionError):
    """Fewer than ``write_quorum`` replicas accepted a write."""


class _RootHealth:
    """Per-root health record (guarded by the router's health lock)."""

    __slots__ = ("down", "fails", "suspect_until")

    def __init__(self):
        self.down = False           # manual/chaos switch: hard-unreachable
        self.fails = 0              # consecutive organic failures
        self.suspect_until = 0.0    # monotonic deadline of the probe backoff

# store.summary() keys that aggregate by plain summation across roots
_SUM_KEYS = ("n_files", "raw_bytes", "stored_bytes", "file_dedup_hits",
             "near_dup_hits")
_SUM_LIFECYCLE_KEYS = ("versions", "live_bytes", "superseded_bytes",
                       "reclaimed_bytes", "collected", "gc_runs",
                       "deleted_files", "compact_runs",
                       "compaction_reclaimed_bytes")


class StoreRouter:
    """Consistent-hash placement of repos over named ``ZLLMStore`` roots.

    ``stores`` is a mapping ``name -> ZLLMStore`` (ordered; names appear in
    stats and in ``?root=`` admin selectors), or a plain sequence of stores
    (auto-named ``r0``, ``r1``, ...). A single-store router is the identity
    — the server wraps every deployment in one so the two topologies share
    a code path.

    ``replicas`` is the copy count per repo (clamped to the root count);
    ``write_quorum`` the acks required before a fan-out write succeeds
    (default: a majority of the replicas).
    """

    # write-path retry policy: a transient root failure gets RETRY_ATTEMPTS
    # tries with exponential backoff; once the health tracker marks the root
    # suspect, later writes fail fast (one try) until the probe deadline
    RETRY_ATTEMPTS = 3
    RETRY_BASE_S = 0.05
    # suspect backoff: BACKOFF_BASE_S * 2^(fails-1), capped
    BACKOFF_BASE_S = 0.5
    BACKOFF_MAX_S = 30.0
    # a repo's read-repair is not rescheduled within this window of the
    # previous one finishing (a persistently-down replica would otherwise
    # enqueue one repair job per failover read)
    READ_REPAIR_COOLDOWN_S = 5.0
    # repair-pending backlog bound: entries expire after the TTL (a sweep
    # covers everything anyway) and the newest-first cap stops a
    # permanently-down replica from growing the set without limit
    REPAIR_PENDING_TTL_S = 3600.0
    REPAIR_PENDING_MAX = 4096

    def __init__(self, stores: Union[Dict[str, ZLLMStore],
                                     Sequence[ZLLMStore], ZLLMStore],
                 *, replicas: int = 1, write_quorum: Optional[int] = None):
        if isinstance(stores, ZLLMStore):
            stores = [stores]
        if not isinstance(stores, dict):
            stores = OrderedDict((f"r{i}", s) for i, s in enumerate(stores))
        if not stores:
            raise ValueError("StoreRouter needs at least one store")
        self.roots: "OrderedDict[str, ZLLMStore]" = OrderedDict(stores)
        self.replicas = max(1, min(int(replicas), len(self.roots)))
        if write_quorum is None:
            write_quorum = self.replicas // 2 + 1  # majority
        if not 1 <= write_quorum <= self.replicas:
            raise ValueError(f"write_quorum={write_quorum} out of range "
                             f"1..{self.replicas}")
        self.write_quorum = int(write_quorum)
        # repo -> root decisions for writes whose ingest job has not
        # registered in file_index yet: a second PUT for the same new repo
        # arriving inside that window must land on the SAME root(s), or the
        # repo splits across roots (severing its dedup/BitX chain).
        # Bounded; stale entries are harmless — membership wins once the
        # ingest lands, and a pending entry names those same roots anyway.
        self._pending_places: "OrderedDict[str, Tuple[str, ...]]" = OrderedDict()
        # health tracker + repos owed a repair pass (straggler writes,
        # failed deletes); anti_entropy() drains the pending set
        self._health: Dict[str, _RootHealth] = {n: _RootHealth()
                                                for n in self.roots}
        self._health_lock = threading.Lock()
        self._ae_lock = threading.Lock()  # one anti-entropy sweep at a time
        # repos owed a repair pass, with the monotonic stamp they were
        # queued at: TTL-expired and size-capped (REPAIR_PENDING_*) so a
        # permanently-down replica cannot grow the backlog forever
        self._repair_pending: "OrderedDict[str, float]" = OrderedDict()
        # read-repair bookkeeping: one in-flight repair per repo, plus a
        # completion stamp for the reschedule cooldown
        self._read_repair_inflight: Set[str] = set()
        self._read_repair_done: Dict[str, float] = {}
        self.read_repairs = 0  # repairs actually scheduled (stats)
        # replication counters (stats + the hinted-handoff "no full sweep"
        # assertion): sweeps run, hints recorded / drained
        self.anti_entropy_sweeps = 0
        self.hints_recorded = 0
        self.hints_drained = 0
        self._hint_drain_inflight = False
        # crash-injection hook (REPLICATION_FAULT_POINTS), mirroring
        # store.fault_hook; never set in production
        self.fault_hook = None
        # remote peers route their wire-protocol fault points (e.g.
        # peer.ship_mid_body) through this router's hook
        for s in self.roots.values():
            if self._is_peer(s) and getattr(s, "fault_hook", None) is None:
                s.fault_hook = self._fault

    def _fault(self, point: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook(point)

    # -- topology kinds ---------------------------------------------------
    @staticmethod
    def _is_peer(store) -> bool:
        """Remote :class:`repro.serve.peer.PeerStore` roots mark themselves
        with ``is_peer`` — they take ships/adopts over the wire but have no
        local bytes, job workers, or hint log of their own."""
        return bool(getattr(store, "is_peer", False))

    def local_items(self) -> List[Tuple[str, ZLLMStore]]:
        return [(n, s) for n, s in self.roots.items() if not self._is_peer(s)]

    def peer_names(self) -> List[str]:
        return [n for n, s in self.roots.items() if self._is_peer(s)]

    def _first_local_up(self, prefer: Iterable[str] = ()) -> Optional[str]:
        """First healthy local root, preferring ``prefer`` order — the
        host for background jobs and the hint log."""
        for name in list(prefer) + [n for n, _ in self.local_items()]:
            store = self.roots.get(name)
            if store is not None and not self._is_peer(store) \
                    and self.is_up(name):
                return name
        return None

    # -- repair-pending backlog (TTL + cap) --------------------------------
    def _note_repair_pending(self, repo_id: str) -> None:
        with self._health_lock:
            self._repair_pending.pop(repo_id, None)
            self._repair_pending[repo_id] = time.monotonic()
            while len(self._repair_pending) > self.REPAIR_PENDING_MAX:
                self._repair_pending.popitem(last=False)  # oldest out

    def _pending_repairs(self) -> Set[str]:
        """Live (non-expired) repair-pending repos; prunes expired entries
        in place. Expiry is safe — the periodic full sweep covers every
        repo regardless; the backlog only prioritizes."""
        cutoff = time.monotonic() - self.REPAIR_PENDING_TTL_S
        with self._health_lock:
            expired = [r for r, ts in self._repair_pending.items()
                       if ts < cutoff]
            for r in expired:
                del self._repair_pending[r]
            return set(self._repair_pending)

    # -- health tracking --------------------------------------------------
    def set_root_down(self, name: str, down: bool = True) -> None:
        """Chaos/admin switch: a down root is hard-unreachable — reads skip
        it, writes fail against it (after the retry dance) and anti-entropy
        neither ships to nor from it until it is brought back up."""
        with self._health_lock:
            h = self._health[name]
            h.down = down
            if not down:
                h.fails = 0
                h.suspect_until = 0.0

    def is_up(self, name: str) -> bool:
        with self._health_lock:
            return not self._health[name].down

    def note_failure(self, name: str) -> None:
        """Organic failure (exception serving from the root): mark it
        suspect with an exponentially growing probe backoff."""
        with self._health_lock:
            h = self._health[name]
            h.fails += 1
            backoff = min(self.BACKOFF_BASE_S * (2 ** (h.fails - 1)),
                          self.BACKOFF_MAX_S)
            h.suspect_until = time.monotonic() + backoff

    def note_success(self, name: str) -> None:
        with self._health_lock:
            h = self._health[name]
            recovered = h.fails > 0
            h.fails = 0
            h.suspect_until = 0.0
        # organic recovery (the health probe just cleared a suspect root):
        # if this root is owed hinted handoffs, schedule their drain now —
        # targeted re-ship instead of waiting for a full sweep. Manual
        # set_root_down(False) deliberately does NOT trigger this: chaos
        # tests heal topology without implying the hints should move.
        if recovered and self._has_hints_for(name):
            self.schedule_hint_drain(peer=name)

    def _probe_ok(self, name: str) -> bool:
        """True when the root may be tried: up, and either healthy or past
        its suspect backoff (the next request doubles as the probe — on
        success ``note_success`` clears the suspicion, on failure
        ``note_failure`` re-suspends it with a longer backoff).

        The probe is CLAIMED single-flight: the first caller to observe an
        expired backoff re-arms ``suspect_until`` for the current backoff
        window before returning True, so concurrent callers keep treating
        the root as suspect (it stays a last-resort candidate) instead of
        all hammering the just-recovered root at once. The claimant's
        request resolves the probe either way — ``note_success`` clears
        the re-armed deadline, ``note_failure`` extends it."""
        with self._health_lock:
            h = self._health[name]
            if h.down:
                return False
            if h.fails == 0:
                return True
            if time.monotonic() < h.suspect_until:
                return False
            backoff = min(self.BACKOFF_BASE_S * (2 ** (h.fails - 1)),
                          self.BACKOFF_MAX_S)
            h.suspect_until = time.monotonic() + backoff
            return True

    def health(self) -> Dict[str, Dict]:
        """Per-root health snapshot (the ``/healthz`` + ``/stats`` field)."""
        out = {}
        with self._health_lock:
            now = time.monotonic()
            for name, h in self._health.items():
                state = ("down" if h.down
                         else "suspect" if now < h.suspect_until else "up")
                out[name] = {"state": state, "consecutive_failures": h.fails}
        return out

    # -- topology ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self.roots)

    def names(self) -> List[str]:
        return list(self.roots)

    def items(self) -> Iterable[Tuple[str, ZLLMStore]]:
        return self.roots.items()

    def store(self, name: str) -> ZLLMStore:
        return self.roots[name]

    @property
    def single(self) -> Optional[ZLLMStore]:
        """The lone store of a single-root router, else None."""
        return next(iter(self.roots.values())) if len(self.roots) == 1 else None

    # -- placement ----------------------------------------------------------
    def place(self, repo_id: str) -> str:
        """Root name owning ``repo_id`` under rendezvous hashing: the root
        whose ``sha256(name | repo_id)`` scores highest. Deterministic,
        state-free, and minimally disruptive when roots are added."""
        return max(self.roots,
                   key=lambda n: hashlib.sha256(
                       f"{n}|{repo_id}".encode()).digest())

    def _membership_root(self, repo_id: str, filename: str) -> Optional[str]:
        """Root already holding ``repo_id`` — by exact key, by another
        file of the same repo (repo-cohesion: one repo, one root), or by
        an in-flight write decision whose ingest job has not registered
        yet. ``list()`` snapshots the keys under the GIL — the background
        ingest worker inserts into ``file_index`` concurrently, and
        iterating the live dict view would race it ("dictionary changed
        size during iteration")."""
        key = f"{repo_id}/{filename}"
        for name, store in self.roots.items():
            if key in store.file_index:  # atomic membership probe
                return name
        prefix = repo_id + "/"
        for name, store in self.roots.items():
            if any(k.startswith(prefix) for k in list(store.file_index)):
                return name
        pend = self._pending_places.get(repo_id)
        return pend[0] if pend else None

    def locate(self, repo_id: str, filename: str = "model.safetensors") -> str:
        """Root name *serving* ``repo_id/filename``: a root that already
        holds the repo (or has a write for it in flight) wins — pre-seeded
        stores, pre-resize placements, not-yet-registered ingest jobs;
        otherwise the hash placement. Reads and writes both route here, so
        re-registrations land beside the generations they supersede."""
        return self._membership_root(repo_id, filename) or self.place(repo_id)

    def store_for(self, repo_id: str,
                  filename: str = "model.safetensors") -> ZLLMStore:
        return self.roots[self.locate(repo_id, filename)]

    def locate_for_write(self, repo_id: str,
                         filename: str = "model.safetensors",
                         base: Optional[str] = None) -> str:
        """Placement for an incoming write. Like :meth:`locate`, but a NEW
        repo that declares a BitX base co-locates with the root serving
        that base — dedup and delta domains are per-root, so scattering a
        family across roots would store every fine-tune standalone. The
        decision is memoized in ``_pending_places`` so a second write for
        the same repo arriving before the first ingest job registers
        still routes to the same root."""
        root = self._membership_root(repo_id, filename)
        if root is None and base:
            bkey = f"{base}/model.safetensors"
            for name, store in self.roots.items():
                if bkey in store.file_index or base in store.base_paths:
                    root = name
                    break
        if root is None:
            root = self.place(repo_id)
        self._remember_places(repo_id, (root,))
        return root

    def _remember_places(self, repo_id: str, roots: Tuple[str, ...]) -> None:
        self._pending_places[repo_id] = roots
        while len(self._pending_places) > 1024:
            self._pending_places.popitem(last=False)

    # -- replica placement ------------------------------------------------
    def candidates(self, repo_id: str) -> List[str]:
        """Every root ordered by rendezvous score, best first — the natural
        replica candidate list (``place()`` is its head)."""
        return sorted(self.roots,
                      key=lambda n: hashlib.sha256(
                          f"{n}|{repo_id}".encode()).digest(),
                      reverse=True)

    def _holds_repo(self, name: str, repo_id: str) -> bool:
        prefix = repo_id + "/"
        return any(k.startswith(prefix)
                   for k in list(self.roots[name].file_index))

    def replica_roots(self, repo_id: str) -> List[str]:
        """The repo's replica group, membership-aware: roots already
        holding the repo come first (in candidate order — pre-seeded stores
        and pre-resize placements keep serving), padded with the best hash
        candidates up to ``replicas``. Never truncates an actual holder."""
        cands = self.candidates(repo_id)
        members = [n for n in cands if self._holds_repo(n, repo_id)]
        group = members + [n for n in cands if n not in members]
        return group[:max(self.replicas, len(members))]

    def read_candidates(self, repo_id: str,
                        filename: str = "model.safetensors") -> List[str]:
        """Replica roots in failover order for a read: probe-eligible roots
        first (healthy, or suspect past their backoff), then still-backed-off
        suspects as a last resort; manually-down roots are excluded — an
        empty list means every replica is down (the server answers 503)."""
        group = self.replica_roots(repo_id)
        up = [n for n in group if self.is_up(n)]
        ready = [n for n in up if self._probe_ok(n)]
        return ready + [n for n in up if n not in ready]

    @staticmethod
    def _state_rank(state: Tuple) -> Tuple:
        """Comparable strength of a ``_key_state`` tuple — the anti-entropy
        winner rule (container generations beat pinned refs beat gone), as
        a fixed-shape tuple so heterogeneous states still compare."""
        if state[0] == "gone":
            return (0, ())
        if state[0] == "container":
            return (2, state[1:])
        return (1, state[1:])

    def read_plan(self, repo_id: str,
                  filename: str = "model.safetensors") -> Tuple[List[str], bool]:
        """``(candidates, divergent)`` for one read. Candidates are
        :meth:`read_candidates` order with one refinement: within the
        probe-ready tier, roots whose index record for the key ranks
        strongest (same winner rule anti-entropy ships by) come first —
        a failover read never serves a weaker validator while a stronger
        replica is ready. ``divergent`` reports whether the up members of
        the group disagree on the key's state; the GET path uses it to
        schedule read-repair instead of waiting for a full sweep."""
        group = self.replica_roots(repo_id)
        up = [n for n in group if self.is_up(n)]
        key = f"{repo_id}/{filename}"
        states = {n: self._key_state(n, key) for n in up}
        divergent = len(set(states.values())) > 1
        ready = [n for n in up if self._probe_ok(n)]
        if divergent:
            ready.sort(key=lambda n: self._state_rank(states[n]),
                       reverse=True)  # stable: group order breaks ties
        return ready + [n for n in up if n not in ready], divergent

    def schedule_read_repair(self, repo_id: str,
                             note: str = "") -> Optional[str]:
        """Enqueue a scoped anti-entropy pass for one repo on a healthy
        root's background job worker — the GET path's repair trigger when
        a failover read succeeded somewhere other than the first replica,
        or :meth:`read_plan` saw divergent per-key state. Per-key diffs
        re-ship over the ``adopt_container`` path exactly as in a sweep,
        just without waiting for one. Deduped to one in-flight repair per
        repo with a post-completion cooldown; returns the job id, or
        ``None`` when deduped or no root is up."""
        now = time.monotonic()
        with self._health_lock:
            if repo_id in self._read_repair_inflight:
                return None
            if now - self._read_repair_done.get(repo_id, -1e9) \
                    < self.READ_REPAIR_COOLDOWN_S:
                return None
            self._read_repair_inflight.add(repo_id)
        healthy = self._first_local_up(prefer=self.replica_roots(repo_id))
        if healthy is None:
            with self._health_lock:
                self._read_repair_inflight.discard(repo_id)
            return None

        def run(rid=repo_id):
            try:
                return self.anti_entropy(repos=[rid])
            finally:
                with self._health_lock:
                    self._read_repair_inflight.discard(rid)
                    self._read_repair_done[rid] = time.monotonic()
                    while len(self._read_repair_done) > 1024:
                        self._read_repair_done.pop(
                            next(iter(self._read_repair_done)))

        try:
            jid = self.roots[healthy].enqueue_repair(
                run, note=note or f"read-repair: {repo_id}")
        except Exception:
            with self._health_lock:
                self._read_repair_inflight.discard(repo_id)
            raise
        self.read_repairs += 1
        return jid

    def write_roots(self, repo_id: str,
                    filename: str = "model.safetensors",
                    base: Optional[str] = None) -> List[str]:
        """Fan-out targets for an incoming write: the replica group, with a
        NEW repo that declares a BitX base co-locating with the base's
        group (dedup/delta domains are per-root — a fine-tune replica on a
        root without the base's containers would store standalone and the
        replicas would diverge). Memoized like :meth:`locate_for_write`."""
        pend = self._pending_places.get(repo_id)
        if pend:
            return list(pend)
        cands = self.candidates(repo_id)
        members = [n for n in cands if self._holds_repo(n, repo_id)]
        if not members and base:
            bgroup = [n for n in self.replica_roots(base)
                      if self._holds_repo(n, base)
                      or base in self.roots[n].base_paths]
            if bgroup:
                cands = bgroup + [n for n in cands if n not in bgroup]
        order = members + [n for n in cands if n not in members]
        targets = tuple(order[:max(self.replicas, len(members))])
        self._remember_places(repo_id, targets)
        return list(targets)

    # -- replicated writes ------------------------------------------------
    def replicated_enqueue(self, spool_path: str, repo_id: str,
                           filename: str,
                           base: Optional[str] = None) -> Dict:
        """Fan a spooled upload out to the repo's replica group: the bytes
        are staged into every target root's spool *first* (each root's
        ingest job owns — and eventually deletes or adopts — its own copy),
        then enqueued per root with retry + exponential backoff. Succeeds
        once ``write_quorum`` roots accepted the job; stragglers that never
        accepted get an asynchronous repair (a scoped anti-entropy pass on
        the first healthy root's job worker) so they converge once back up.
        Raises :class:`QuorumError` below quorum."""
        targets = self.write_roots(repo_id, filename, base)
        staged: Dict[str, str] = {}
        for name in targets:
            sdir = self.roots[name].spool_dir()
            if os.path.dirname(os.path.abspath(spool_path)) == \
                    os.path.abspath(sdir):
                staged[name] = spool_path
                continue
            dst = os.path.join(sdir, f"fanout-{os.getpid()}-"
                                     f"{os.path.basename(spool_path)}")
            with open(spool_path, "rb") as fin, open(dst, "wb") as fout:
                while True:
                    chunk = fin.read(1 << 20)
                    if not chunk:
                        break
                    fout.write(chunk)
            staged[name] = dst
        jobs: "OrderedDict[str, str]" = OrderedDict()
        failed: List[str] = []
        quorum_fired = False
        for i, name in enumerate(targets):
            if i == 1:
                self._fault("put.mid_fanout")
            if len(jobs) >= self.write_quorum and not quorum_fired:
                quorum_fired = True
                self._fault("put.post_quorum")
            jid = self._enqueue_with_retry(name, staged[name], repo_id,
                                           filename, base)
            if jid is None:
                failed.append(name)
            else:
                jobs[name] = jid
        if failed and jobs:
            # hinted handoff: each missed replica gets a durable per-peer
            # hint (key + the staged spool copy) on a healthy local root;
            # the drainer re-ships exactly these keys when the replica's
            # health probe recovers — no full sweep needed for a blip.
            # Recording falls back to the repair-pending backlog (next
            # sweep) when no local root can host the hint log.
            for name in failed:
                if self._record_hint(name, repo_id, filename,
                                     staged.get(name), base) is None:
                    self._note_repair_pending(repo_id)
            healthy = self._first_local_up(prefer=list(jobs))
            if healthy is not None:
                self.roots[healthy].enqueue_repair(
                    lambda rid=repo_id: self.anti_entropy(repos=[rid]),
                    note=f"straggler repair: {repo_id} missed "
                         f"{','.join(failed)}")
        elif failed:
            for name in failed:  # no quorum: the staged copies have no owner
                try:
                    os.remove(staged[name])
                except OSError:
                    pass
        if len(jobs) < self.write_quorum:
            raise QuorumError(
                f"write quorum not met for {repo_id}/{filename}: "
                f"{len(jobs)}/{self.write_quorum} of {len(targets)} replicas "
                f"accepted (failed: {', '.join(failed) or 'none'})")
        return {"jobs": dict(jobs), "targets": targets, "failed": failed,
                "quorum": self.write_quorum}

    def _enqueue_with_retry(self, name: str, path: str, repo_id: str,
                            filename: str,
                            base: Optional[str]) -> Optional[str]:
        """Enqueue one replica's ingest job. A root the health tracker
        already distrusts gets a single fast-fail attempt; otherwise the
        full retry + exponential backoff dance (a transiently down root
        that recovers mid-retry still takes the write)."""
        attempts = self.RETRY_ATTEMPTS if self._probe_ok(name) else 1
        store = self.roots[name]
        for i in range(attempts):
            try:
                if not self.is_up(name):
                    raise RootDownError(f"root {name} is down")
                jid = store.enqueue_ingest(
                    [(path, repo_id, filename, base)], cleanup=True)
            except Exception:
                if i + 1 < attempts:
                    time.sleep(self.RETRY_BASE_S * (2 ** i))
                continue
            self.note_success(name)
            return jid
        self.note_failure(name)
        return None

    def await_quorum(self, jobs: Dict[str, str],
                     timeout: float = 600.0) -> Tuple[bool, Dict[str, Dict]]:
        """Block until ``write_quorum`` of the given per-root jobs reached
        ``done`` (True) or enough failed/timed out that the quorum is
        unreachable (False). Returns the final per-root job status dicts."""
        need = min(self.write_quorum, len(jobs))
        deadline = time.monotonic() + timeout
        while True:
            states = {n: self.roots[n].ingest_job(j) for n, j in jobs.items()}
            done = sum(1 for s in states.values()
                       if s is not None and s["state"] == "done")
            dead = sum(1 for s in states.values()
                       if s is None or s["state"] == "failed")
            if done >= need:
                return True, states
            if len(jobs) - dead < need or time.monotonic() > deadline:
                return False, states
            time.sleep(0.02)

    # -- replicated delete ------------------------------------------------
    def delete(self, repo_id: str, filename: Optional[str] = None) -> Dict:
        """Delete a file (or a whole repo) on every replica in the group,
        persisting each root's index so the tombstones survive a restart.
        Idempotent — deleting what isn't there reports 0. Down roots are
        skipped and the repo is queued for anti-entropy (the tombstones on
        the surviving replicas propagate once the root returns)."""
        group = self.replica_roots(repo_id)
        counts: Dict[str, int] = {}
        failed: List[str] = []
        for name in group:
            if not self.is_up(name):
                failed.append(name)
                continue
            store = self.roots[name]
            try:
                if filename is not None:
                    n = int(store.delete_file(repo_id, filename))
                else:
                    n = store.delete_repo(repo_id)
                store.save_index()  # tombstone durability
                counts[name] = n
                self.note_success(name)
            except Exception:
                self.note_failure(name)
                failed.append(name)
        if failed:
            self._note_repair_pending(repo_id)
        return {"deleted": max(counts.values(), default=0),
                "roots": counts, "failed": failed}

    # -- hinted handoff ----------------------------------------------------
    # A quorum write below full fan-out owes the missed replica its bytes.
    # Rather than waiting for a full anti-entropy sweep, the router records
    # a durable per-peer hint (key + staged spool bytes) on a healthy local
    # root (``ZLLMStore.record_hint`` — fsync'd JSONL beside the index) and
    # re-ships exactly the hinted keys once the peer's health probe
    # recovers (``note_success`` after a suspect streak).

    def _record_hint(self, peer: str, repo_id: str, filename: str,
                     staged: Optional[str],
                     base: Optional[str]) -> Optional[str]:
        """Durably record one handoff hint; the staged fan-out copy moves
        into the hint host's spool so it survives until the drain. Returns
        ``None`` (caller falls back to the repair-pending backlog) when no
        local root can host the log."""
        host_name = self._first_local_up()
        if host_name is None:
            if staged:
                try:
                    os.remove(staged)
                except OSError:
                    pass
            return None
        host = self.roots[host_name]
        ref: Optional[str] = None
        if staged and os.path.exists(staged):
            ref = os.path.join(host.spool_dir(),
                               f"hint-{os.getpid()}-"
                               f"{os.path.basename(staged)}")
            if os.path.abspath(ref) == os.path.abspath(staged):
                ref = staged
            else:
                try:
                    os.replace(staged, ref)
                except OSError:
                    try:  # cross-filesystem staging (a peer's tempdir)
                        with open(staged, "rb") as fin, \
                                open(ref, "wb") as fout:
                            while True:
                                chunk = fin.read(1 << 20)
                                if not chunk:
                                    break
                                fout.write(chunk)
                        os.remove(staged)
                    except OSError:
                        ref = None
        hid = host.record_hint(peer, repo_id, filename, ref, base=base)
        self.hints_recorded += 1
        return hid

    def _has_hints_for(self, peer: str) -> bool:
        for _, host in self.local_items():
            try:
                if host.pending_hints(peer):
                    return True
            except Exception:
                continue
        return False

    def pending_hint_count(self, peer: Optional[str] = None) -> int:
        return sum(len(host.pending_hints(peer))
                   for _, host in self.local_items())

    def _peer_alive(self, name: str) -> bool:
        """Is the replica actually reachable right now? Local roots are
        alive when up; a remote peer gets a real ``/healthz`` probe —
        draining hints into a half-recovered peer would just re-fail."""
        if not self.is_up(name):
            return False
        store = self.roots[name]
        if self._is_peer(store):
            return bool(store.probe())
        return True

    def schedule_hint_drain(self, peer: Optional[str] = None,
                            note: str = "") -> Optional[str]:
        """Run :meth:`drain_hints` on a healthy local root's background
        job worker (single-flight — recovery storms collapse into one
        drain). Returns the job id, or ``None`` when deduped or no local
        root is up."""
        with self._health_lock:
            if self._hint_drain_inflight:
                return None
            self._hint_drain_inflight = True
        host_name = self._first_local_up()
        if host_name is None:
            with self._health_lock:
                self._hint_drain_inflight = False
            return None

        def run(p=peer):
            try:
                return self.drain_hints(peer=p)
            finally:
                with self._health_lock:
                    self._hint_drain_inflight = False

        try:
            return self.roots[host_name].enqueue_repair(
                run, note=note or f"hint drain: {peer or 'all peers'}")
        except Exception:
            with self._health_lock:
                self._hint_drain_inflight = False
            raise

    def drain_hints(self, peer: Optional[str] = None) -> Dict:
        """Re-ship every recorded hint (optionally one peer's) whose
        target is reachable: exactly the hinted keys move — by closure
        ship from the strongest live source, falling back to re-ingesting
        the staged spool bytes — and drained hints leave the log
        atomically. Unreachable targets keep their hints for the next
        recovery. This is the targeted alternative to a full sweep: it
        never diffs, never touches unhinted keys, and does not bump
        ``anti_entropy_sweeps``."""
        report = {"drained": 0, "kept": 0, "requeued": 0,
                  "shipped_versions": 0, "shipped_bytes": 0,
                  "records_updated": 0, "errors": []}
        alive: Dict[str, bool] = {}
        for host_name, host in self.local_items():
            hints = host.pending_hints(peer)
            if not hints:
                continue
            done: List[str] = []
            for h in hints:
                tgt = h.get("peer")
                if tgt not in self.roots:
                    done.append(h["id"])  # replica left the topology
                    continue
                if tgt not in alive:
                    alive[tgt] = self._peer_alive(tgt)
                if not alive[tgt]:
                    report["kept"] += 1
                    continue
                try:
                    ok = self._drain_one_hint(h, report)
                except Exception as e:
                    report["errors"].append(
                        f"hint {h.get('id')} -> {tgt}: "
                        f"{type(e).__name__}: {e}")
                    report["kept"] += 1
                    alive[tgt] = self._peer_alive(tgt)  # it may have died
                    continue
                if ok:
                    done.append(h["id"])
                else:
                    report["kept"] += 1
            if done:
                # crash window under test: the re-ship landed but the log
                # has not dropped the hint — recovery re-drains; shipping
                # is idempotent, so the replay converges to the same state
                self._fault("hint.pre_drain_persist")
                dropped = host.drop_hints(done)
                self.hints_drained += dropped
                report["drained"] += dropped
        return report

    def _drain_one_hint(self, h: Dict, report: Dict) -> bool:
        """Converge one hinted key on its target. True == the debt is
        settled (shipped, already converged, deletion won, or re-queued
        into the target's own ingest) and the hint may drop."""
        tgt = h["peer"]
        repo_id, filename = h["repo_id"], h["filename"]
        key = f"{repo_id}/{filename}"
        t_store = self.roots[tgt]
        if self._is_peer(t_store):
            t_store.refresh_snapshot()
        tgt_state = self._key_state(tgt, key)
        sources = {}
        for n, s in self.local_items():
            if n != tgt and self.is_up(n):
                st = self._key_state(n, key)
                if st[0] != "gone":
                    sources[n] = st
        if sources:
            src = max(sources, key=lambda n: self._state_rank(sources[n]))
            if sources[src] == tgt_state:
                return True  # a sweep or earlier drain got there first
            src_rec = self.roots[src].file_index.get(key)
            if src_rec is None:
                return False
            if tgt_state[0] == "gone" and self._tombstone_wins(
                    t_store, key, src_rec):
                return True  # the write was deleted meanwhile: debt void
            self._ship_key(src, tgt, key, src_rec, report)
            return True
        # no live local source. A local tombstone means the hinted write
        # was deleted meanwhile — re-ingesting the staged bytes would
        # mint a generation ABOVE the marker's and resurrect the key on
        # the next sweep, so the debt is void instead.
        for n, s in self.local_items():
            if self.is_up(n) and key in s.lifecycle.tombstones:
                return True
        # otherwise the local job is likely still in flight: if the
        # staged bytes survive, hand them to the target's own ingest
        # pipeline; failing that keep the hint for the next pass
        ref = h.get("spool_ref")
        if ref and os.path.exists(ref):
            dst = os.path.join(t_store.spool_dir(),
                               f"hintship-{os.path.basename(ref)}")
            with open(ref, "rb") as fin, open(dst, "wb") as fout:
                while True:
                    chunk = fin.read(1 << 20)
                    if not chunk:
                        break
                    fout.write(chunk)
            t_store.enqueue_ingest(
                [(dst, repo_id, filename, h.get("base"))], cleanup=True)
            report["requeued"] += 1
            return True
        return False

    # -- anti-entropy -----------------------------------------------------
    def _all_repos(self) -> Set[str]:
        repos: Set[str] = set()
        for store in self.roots.values():
            for k in list(store.file_index):
                repos.add(k.rsplit("/", 1)[0])
            for k in list(store.lifecycle.tombstones):
                repos.add(k.rsplit("/", 1)[0])
        return repos

    def anti_entropy(self, repos: Optional[Sequence[str]] = None,
                     ) -> Dict:
        """One repair sweep over every replica group (or just ``repos``):

        1. **Tombstones** — delete markers are unioned across the group and
           applied everywhere, so no replica resurrects a deleted repo (a
           record whose generation exceeds the marker's survives: that is a
           legitimate re-upload, generations being monotonic per key).
        2. **Quarantine-restore** — a quarantined container with a healthy
           same-``(key, gen)`` copy on another replica is re-fetched,
           sha256-verified and swapped back in.
        3. **Re-ship** — per key, the best record (highest container
           generation) wins; replicas missing it receive the pinned
           generation's full dependency closure as verbatim container
           bytes, then the index record itself.

        Touched roots persist their index and take a light structural
        ``fsck`` at the end. Sweeps serialize on a router-level lock."""
        with self._ae_lock:
            self.anti_entropy_sweeps += 1
            report = {"repos": 0, "tombstones_applied": 0, "restored": 0,
                      "shipped_versions": 0, "shipped_bytes": 0,
                      "records_updated": 0, "skipped_roots": [],
                      "errors": []}
            pending = self._pending_repairs()
            todo = sorted(set(repos) if repos is not None
                          else self._all_repos() | pending)
            for repo in todo:
                try:
                    self._anti_entropy_repo(repo, report)
                except Exception as e:  # keep sweeping other groups
                    report["errors"].append(f"{repo}: {type(e).__name__}: {e}")
                report["repos"] += 1
            with self._health_lock:
                for repo in todo:
                    self._repair_pending.pop(repo, None)
            touched = report.pop("_touched", set())
            for name in touched:
                store = self.roots[name]
                try:  # a peer may die between its adopt and this persist
                    store.save_index()
                    rep = store.fsck(repair=True, spot_check=0)
                except Exception as e:
                    self.note_failure(name)
                    report["errors"].append(
                        f"post-repair persist on {name}: "
                        f"{type(e).__name__}: {e}")
                    continue
                if not rep.ok:
                    report["errors"].append(
                        f"post-repair fsck on {name}: "
                        f"{rep.summary()}")
            report["touched_roots"] = sorted(touched)
            return report

    def _anti_entropy_repo(self, repo_id: str, report: Dict) -> None:
        group = self.replica_roots(repo_id)
        up = [n for n in group if self.is_up(n)]
        # remote peers must be diffed against LIVE state, not a cached
        # snapshot: refresh over the wire, and treat an unreachable peer
        # exactly like a down root (skip; it converges once back)
        live = []
        for n in up:
            store = self.roots[n]
            if self._is_peer(store):
                try:
                    store.refresh_snapshot()
                except Exception:
                    self.note_failure(n)
                    continue
            live.append(n)
        up = live
        skipped = [n for n in group if n not in up]
        for n in skipped:
            if n not in report["skipped_roots"]:
                report["skipped_roots"].append(n)
        if not up:
            return
        touched: Set[str] = report.setdefault("_touched", set())
        prefix = repo_id + "/"

        # 1. union + apply tombstones
        tombs: Dict[str, Tuple[int, float]] = {}
        for n in up:
            for k, (g, ts) in list(
                    self.roots[n].lifecycle.tombstones.items()):
                if not k.startswith(prefix):
                    continue
                old = tombs.get(k)
                if old is None or g > old[0]:
                    tombs[k] = (g, ts)
        for k, (g, ts) in tombs.items():
            for n in up:
                if self.roots[n].apply_tombstone(k, g, ts):
                    report["tombstones_applied"] += 1
                    touched.add(n)

        # 2. quarantine-restore from healthy same-generation copies
        for n in up:
            store = self.roots[n]
            for v in [v for v in list(store.lifecycle.versions.values())
                      if v.quarantined and v.key.startswith(prefix)]:
                for donor in up:
                    if donor == n:
                        continue
                    dstore = self.roots[donor]
                    if not dstore.lifecycle.exists(v.key, v.gen):
                        continue
                    digest = dstore.container_digest(v.key, v.gen)
                    staged = self._stage_version(
                        dstore, v.key, v.gen, store.spool_dir(),
                        f"restore-{v.vid.replace('/', '__')}")
                    self._fault("restore.mid_copy")
                    if store.restore_version(v.key, v.gen, staged,
                                             expected_sha256=digest):
                        report["restored"] += 1
                        touched.add(n)
                    break

        # 3. diff per-key states, ship the winner's closure verbatim
        keys: Set[str] = set()
        for n in up:
            keys.update(k for k in list(self.roots[n].file_index)
                        if k.startswith(prefix))
        for key in sorted(keys):
            states = {n: self._key_state(n, key) for n in up}
            live = {n: s for n, s in states.items() if s[0] != "gone"}
            if not live or len(set(live.values())) == 1 and len(live) == len(up):
                continue
            src = max(live, key=lambda n: (live[n][0] == "container",
                                           live[n][1:]))
            src_rec = self.roots[src].file_index.get(key)
            if src_rec is None:
                continue
            for tgt in up:
                if tgt == src or states.get(tgt) == states[src]:
                    continue
                if states[tgt][0] == "gone" and self._tombstone_wins(
                        self.roots[tgt], key, src_rec):
                    continue  # deletion wins over the source's record
                try:
                    self._ship_key(src, tgt, key, src_rec, report)
                    touched.add(tgt)
                except Exception as e:
                    report["errors"].append(
                        f"ship {key} {src}->{tgt}: {type(e).__name__}: {e}")

    @staticmethod
    def _tombstone_wins(store: ZLLMStore, key: str, src_rec: Dict) -> bool:
        """Does ``store``'s delete marker for ``key`` cover the source
        replica's record? Containers compare monotonic generations; ref
        records (no generation of their own) resolve last-writer-wins on
        the record's write stamp — mirrors ``apply_tombstone``."""
        tomb = store.lifecycle.tombstone_for(key)
        if tomb is None:
            return False
        gen, ts = tomb
        if src_rec.get("kind") == "container":
            return int(src_rec.get("gen", 0)) <= gen
        return float(src_rec.get("mtime", 0.0)) <= ts

    def _key_state(self, name: str, key: str) -> Tuple:
        """Comparable per-root state of one index key: what generation (or
        pinned ref) the root serves, or ``gone`` (deleted / never seen —
        indistinguishable on purpose: neither serves bytes)."""
        rec = self.roots[name].file_index.get(key)
        if rec is None:
            return ("gone",)
        if rec.get("kind") == "container":
            return ("container", int(rec.get("gen", 0)))
        return (rec["kind"], rec.get("ref", ""), int(rec.get("ref_gen", 0)),
                rec.get("file_hash", ""))

    def _stage_version(self, src_store, key: str, gen: int, dst_dir: str,
                       name: str) -> str:
        """Materialize one container version's verbatim bytes as a local
        file in ``dst_dir``: a local source is copied, a remote peer's is
        fetched over the wire (resumable, sha256-verified)."""
        if self._is_peer(src_store):
            return src_store.fetch_container(key, gen, dst_dir)
        src_path = src_store.lifecycle.version_path(key, gen)
        staged = os.path.join(dst_dir, name)
        with open(src_path, "rb") as fin, open(staged, "wb") as fout:
            while True:
                chunk = fin.read(1 << 20)
                if not chunk:
                    break
                fout.write(chunk)
        return staged

    def _ship_key(self, src: str, tgt: str, key: str, rec: Dict,
                  report: Dict) -> None:
        """Re-ship one key from ``src`` to ``tgt``: the pinned generation's
        dependency closure as verbatim container bytes (dependencies first,
        adoption is idempotent), then the index record. Either side may be
        a remote peer — a local source ships its container file directly, a
        peer source is first staged locally; ``adopt_container`` is the
        polymorphic seam (in-process temp+rename vs. resumable upload)."""
        s_store, t_store = self.roots[src], self.roots[tgt]
        if rec.get("kind") == "container":
            anchor = make_vid(key, int(rec.get("gen", 0)))
        else:
            anchor = make_vid(rec["ref"], int(rec.get("ref_gen", 0)))
        for vid in self._closure_postorder(s_store, anchor):
            v = s_store.lifecycle.versions.get(vid)
            if v is None or v.quarantined:
                continue  # another replica may donate it later
            vkey, _, vgen = vid.rpartition("@g")
            vgen = int(vgen)
            if t_store.lifecycle.get(vkey, vgen) is not None:
                continue
            digest = s_store.container_digest(vkey, vgen)
            if self._is_peer(s_store):
                src_path = self._stage_version(
                    s_store, vkey, vgen, t_store.spool_dir(),
                    f"ship-{vid.replace('/', '__')}")
                cleanup = True
            else:
                src_path, cleanup = v.path, False
            self._fault("anti_entropy.mid_copy")
            try:
                if t_store.adopt_container(vkey, vgen, src_path,
                                           expected_sha256=digest):
                    report["shipped_versions"] += 1
                    report["shipped_bytes"] += v.nbytes
            finally:
                if cleanup:
                    try:
                        os.remove(src_path)
                    except OSError:
                        pass
        t_store.adopt_index_record(key, rec)
        report["records_updated"] += 1

    @staticmethod
    def _closure_postorder(store: ZLLMStore, anchor: str) -> List[str]:
        """Dependency-first (postorder) walk of the version graph from
        ``anchor``: a shipped container's edges must resolve on the target,
        so its targets land before it does."""
        out: List[str] = []
        seen: Set[str] = set()
        stack: List[Tuple[str, bool]] = [(anchor, False)]
        while stack:
            vid, expanded = stack.pop()
            if expanded:
                out.append(vid)
                continue
            if vid in seen or vid not in store.lifecycle.versions:
                continue
            seen.add(vid)
            stack.append((vid, True))
            for dst in store.lifecycle.edges.get(vid, ()):
                if dst not in seen:
                    stack.append((dst, False))
        return out

    def replica_index_diff(self, repos: Optional[Sequence[str]] = None,
                           ) -> Dict[str, Dict[str, Dict[str, List]]]:
        """Per-replica-group index disagreements among up roots: empty dict
        == every group converged (the smoke/soak convergence assertion).
        Keys map to per-root states (``["container", gen]`` / ref tuples /
        ``["gone"]``); only keys with >1 distinct state appear."""
        out: Dict[str, Dict[str, Dict[str, List]]] = {}
        todo = sorted(set(repos) if repos is not None else self._all_repos())
        for repo in todo:
            up = [n for n in self.replica_roots(repo) if self.is_up(n)]
            prefix = repo + "/"
            keys: Set[str] = set()
            for n in up:
                keys.update(k for k in list(self.roots[n].file_index)
                            if k.startswith(prefix))
                keys.update(k for k in list(
                    self.roots[n].lifecycle.tombstones) if k.startswith(prefix))
            rdiff: Dict[str, Dict[str, List]] = {}
            for key in sorted(keys):
                states = {n: self._key_state(n, key) for n in up}
                if len(set(states.values())) > 1:
                    rdiff[key] = {n: list(s) for n, s in states.items()}
            if rdiff:
                out[repo] = rdiff
        return out

    # -- aggregate stats ------------------------------------------------------
    def summary(self) -> Dict:
        """Aggregated ``store.summary()``. Single root: the flat summary,
        unchanged (back-compat for ``server_smoke`` and /stats consumers).
        N roots: summable counters aggregated at the top plus the full
        per-root summaries under ``roots``."""
        single = self.single
        if single is not None:
            return single.summary()
        per_root = {name: store.summary() for name, store in self.roots.items()}
        agg: Dict = {k: sum(s[k] for s in per_root.values()) for k in _SUM_KEYS}
        agg["reduction_ratio"] = round(
            1.0 - agg["stored_bytes"] / agg["raw_bytes"], 4
        ) if agg["raw_bytes"] else 0.0
        agg["lifecycle"] = {k: sum(s["lifecycle"][k] for s in per_root.values())
                            for k in _SUM_LIFECYCLE_KEYS}
        agg["lifecycle"]["gc_max_pause_ms"] = max(
            s["lifecycle"]["gc_max_pause_ms"] for s in per_root.values())
        agg["read_gen"] = {name: s["read_gen"] for name, s in per_root.items()}
        agg["n_roots"] = len(per_root)
        agg["roots"] = per_root
        with self._health_lock:
            pending = len(self._repair_pending)
        agg["replication"] = {"replicas": self.replicas,
                              "write_quorum": self.write_quorum,
                              "health": self.health(),
                              "repair_pending": pending,
                              "read_repairs": self.read_repairs,
                              "anti_entropy_sweeps": self.anti_entropy_sweeps,
                              "hints_recorded": self.hints_recorded,
                              "hints_drained": self.hints_drained,
                              "hints_pending": self.pending_hint_count(),
                              "peers": self.peer_names()}
        return agg

    def ingest_jobs(self, limit: int = 64) -> List[Dict]:
        """Recent spooled-ingest jobs across every root (each row carries
        its ``root``), newest first."""
        rows: List[Dict] = []
        for name, store in self.roots.items():
            for j in store.ingest_jobs(limit):
                j["root"] = name
                rows.append(j)
        rows.sort(key=lambda j: j["enqueued_at"], reverse=True)
        return rows[:limit]

    def ingest_job(self, job_id: str) -> Optional[Dict]:
        """Look a job id up across roots (ids are store-local)."""
        for name, store in self.roots.items():
            j = store.ingest_job(job_id)
            if j is not None:
                j["root"] = name
                return j
        return None

    # -- admin fan-out ------------------------------------------------------
    def _selected(self, root: Optional[str]) -> List[Tuple[str, ZLLMStore]]:
        if root is None:
            return list(self.roots.items())
        if root not in self.roots:
            raise KeyError(f"unknown root {root!r} "
                           f"(have: {', '.join(self.roots)})")
        return [(root, self.roots[root])]

    def fanout_gc(self, root: Optional[str] = None, *, incremental: bool = False,
                  max_pause_ms: float = 50.0) -> Dict:
        reports = {name: store.gc(incremental=incremental,
                                  max_pause_ms=max_pause_ms)
                   for name, store in self._selected(root)}
        return self._flat_or_nested(reports, ("collected", "reclaimed_bytes"))

    def fanout_compact(self, root: Optional[str] = None) -> Dict:
        reports = {name: store.compact()
                   for name, store in self._selected(root)}
        return self._flat_or_nested(
            reports, ("retired_versions", "moved_records",
                      "net_reclaimed_bytes"))

    def fanout_fsck(self, root: Optional[str] = None, *, repair: bool = False,
                    spot_check: Optional[int] = 4) -> Dict:
        reports = {}
        for name, store in self._selected(root):
            rep = store.fsck(repair=repair, spot_check=spot_check)
            reports[name] = {"ok": rep.ok, "summary": rep.summary(),
                             "orphans": len(rep.orphans),
                             "quarantined": len(rep.quarantined)}
        if len(reports) == 1 and len(self.roots) == 1:
            return next(iter(reports.values()))
        out = {"roots": reports, "ok": all(r["ok"] for r in reports.values())}
        return out

    def _flat_or_nested(self, reports: Dict[str, Dict],
                        sum_keys: Tuple[str, ...]) -> Dict:
        """One root selected on a single-root router → the flat report
        (back-compat); otherwise per-root reports plus summed headline
        numbers."""
        if len(reports) == 1 and len(self.roots) == 1:
            return next(iter(reports.values()))
        out: Dict = {k: sum(r.get(k, 0) for r in reports.values())
                     for k in sum_keys}
        out["roots"] = reports
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Close every store exactly once (dict values may repeat when the
        same store is mounted under two names)."""
        for store in {id(s): s for s in self.roots.values()}.values():
            store.close()

    @staticmethod
    def open_roots(paths: Sequence[str], *, workers: int = 2,
                   replicas: int = 1,
                   write_quorum: Optional[int] = None,
                   peers: Sequence[str] = ()) -> "StoreRouter":
        """CLI helper: open one store per path (index loaded when present),
        named ``r0..rN`` with the path recorded for display. ``peers`` are
        remote replica URLs, mounted as ``p0..pN``
        :class:`repro.serve.peer.PeerStore` roots behind the same
        interface — replica groups may then span server processes."""
        stores: "OrderedDict[str, ZLLMStore]" = OrderedDict()
        for i, path in enumerate(paths):
            store = ZLLMStore(path, workers=workers)
            store.load_index()
            stores[f"r{i}"] = store
        if peers:
            from repro.serve.peer import PeerStore
            for i, url in enumerate(peers):
                stores[f"p{i}"] = PeerStore(url)
        router = StoreRouter(stores, replicas=replicas,
                             write_quorum=write_quorum)
        # wire-protocol fault points fire through the router's hook
        for s in stores.values():
            if getattr(s, "is_peer", False):
                s.fault_hook = router._fault
        return router
