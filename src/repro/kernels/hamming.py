"""Pallas TPU kernel for the paper's bit distance (Eq. 1): XOR + popcount + reduce.

The bit distance D(w, ŵ) = (1/n) Σ H(wᵢ, ŵᵢ) drives LLM family clustering
(§3.4.3) and base-model matching (§4.4.3 step 3b). The hot loop is
XOR → population_count → sum, which on TPU is a VPU-native pipeline
(``population_count`` lowers to a hardware op).

Reduction strategy: a grid of row-blocks each writes one uint32 partial sum
(a 256×1024 uint16 block can contribute at most 256·1024·16 = 2²² differing
bits, far below uint32 overflow); the host-side wrapper sums partials in
uint64. This two-stage tree avoids cross-block accumulation hazards and keeps
the kernel embarrassingly parallel — the property the paper exploits for
line-rate ingestion throughput.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitx_xor import DEFAULT_BLOCK_ROWS

__all__ = ["hamming_partials_2d", "hamming_total_2d"]


def _hamming_kernel(a_ref, b_ref, o_ref):
    delta = jnp.bitwise_xor(a_ref[...], b_ref[...])
    pc = jax.lax.population_count(delta).astype(jnp.uint32)
    o_ref[0] = jnp.sum(pc, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hamming_partials_2d(
    a: jax.Array,
    b: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Per-block popcount partial sums over a 2D bit view. Returns (grid,) u32."""
    rows, cols = a.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = rows // block_rows
    in_spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    out_spec = pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        _hamming_kernel,
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.uint32),
        in_specs=[in_spec, in_spec],
        out_specs=out_spec,
        grid=(grid,),
        interpret=interpret,
    )(a, b)


def hamming_total_2d(
    a: jax.Array,
    b: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> int:
    """Total differing bits between two 2D bit views.

    Final reduction happens host-side in uint64: under 32-bit jax mode a
    device-side uint64 sum silently truncates, and embedding-scale tensors can
    exceed 2³² differing bits.
    """
    partials = hamming_partials_2d(a, b, block_rows=block_rows, interpret=interpret)
    import numpy as np

    return int(np.asarray(partials).astype(np.uint64).sum())
