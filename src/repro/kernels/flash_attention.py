"""Pallas TPU flash-attention kernel (forward) — the beyond-paper §Perf lever
for the attention-memory-bound training/prefill cells.

The dry-run hotspot analysis (launch/hlo_hotspots.py) shows that XLA-compiled
chunked attention writes every (chunk_q × chunk_kv) score block to HBM (XLA
cannot fuse through the two dots), making train_4k/prefill_32k memory-bound:
~6 HBM visits × 4 B per score element. This kernel keeps the score block in
VMEM: per (q-block, kv-sweep) the only HBM traffic is the q/k/v tiles and the
output tile — the classic flash-attention traffic model.

Grid: (batch·heads, nq). Each program owns one (block_q × D) query tile and
sweeps the KV sequence in (block_kv × D) tiles with an online-softmax
accumulator held in VMEM scratch. Causality and sliding windows are applied
via position masks computed from the grid indices.

VMEM budget per core (v5e ~16 MiB): q tile 128·128·4 + k/v tiles 2·512·128·4
+ scores 128·512·4 + acc 128·128·4 ≈ 1 MiB — comfortable; block sizes are
MXU-aligned multiples of 128.

The backward pass stays with the checkpointed XLA path (recompute-based);
a fused flash backward is listed as future work in EXPERIMENTS.md §Perf.
Validated in interpret mode against ``ref.mha_reference`` over shape/dtype
sweeps (tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention", "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_KV"]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                  block_q, block_kv, seq_kv):
    """One (q-block) program: sweep kv blocks with online softmax.

    q_ref: (block_q, D); k_ref/v_ref: (seq_kv, D) — full K/V rows for this
    (batch, head); o_ref: (block_q, D).
    """
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale      # (block_q, D)
    D = q.shape[-1]

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, D), jnp.float32)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)

    def body(kj, carry):
        m, l, acc = carry
        # leading dim via a length-1 dslice: jax 0.4.3x's interpret-mode
        # discharge rule rejects bare int indices inside pl.load
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kj * block_kv, block_kv),
                            slice(None)))[0]
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kj * block_kv, block_kv),
                            slice(None)))[0]
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bkv)
        k_pos = kj * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    nk = seq_kv // block_kv
    if causal:
        # skip kv blocks strictly above the diagonal for this q block
        nk_eff = jnp.minimum(nk, (qi + 1) * block_q // block_kv + 1)
    else:
        nk_eff = nk
    m, l, acc = jax.lax.fori_loop(0, nk_eff, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, H, D) (GQA pre-expanded). Returns
    (B, Sq, H, D). Sq % block_q == 0, Sk % block_kv == 0."""
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    assert Hk == H, (Hk, H)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    assert Sq % block_q == 0 and Sk % block_kv == 0, (Sq, Sk, block_q, block_kv)
    scale = 1.0 / (D ** 0.5)

    # fold (B, H) into the leading grid dim; kernel sees one head's rows
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)

    grid = (B * H, Sq // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_kv=Sk)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Sk, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i: (b, i, 0)),
        grid=grid,
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
