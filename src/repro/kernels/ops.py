"""Public jit'd API over the Pallas kernels, with shape/dtype plumbing.

Callers hand in arbitrary-shaped arrays (float or bit-view); this module owns:

* bitcasting floats to unsigned bit views (bf16→u16, f32→u32, …),
* flattening + padding to (rows, 1024) tiles the kernels expect,
* choosing ``interpret=True`` off-TPU (this container is CPU-only; interpret
  mode executes the kernel body for validation, TPU is the deployment target),
* un-padding / reshaping results back.

A pure-numpy path (``backend="numpy"``) is also provided: the storage pipeline
uses it for host-side ingestion of mmap'd tensors where device transfer would
dominate; tests assert the numpy, jnp-ref and Pallas paths agree bit-exactly.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitx_xor as _bitx
from repro.kernels import byte_planes as _bp
from repro.kernels import hamming as _ham
from repro.kernels import ref as _ref

__all__ = [
    "bit_view_dtype",
    "to_bit_view",
    "bitx_encode_planes",
    "bitx_decode_planes",
    "zipnn_split_planes",
    "zipnn_merge_planes",
    "hamming_total",
    "bit_distance",
]

LANES = _bitx.LANES

_FLOAT_TO_UINT = {
    "bfloat16": jnp.uint16,
    "float16": jnp.uint16,
    "float32": jnp.uint32,
    "float64": jnp.uint64,
}


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bit_view_dtype(dtype) -> jnp.dtype:
    """Unsigned bit-view dtype for a float (or passthrough for uints)."""
    d = jnp.dtype(dtype)
    if d.name in _FLOAT_TO_UINT:
        return jnp.dtype(_FLOAT_TO_UINT[d.name])
    if d.kind == "u":
        return d
    raise ValueError(f"no bit view for dtype {d}")


def to_bit_view(x: jax.Array) -> jax.Array:
    """Bitcast to the unsigned view (no-op if already unsigned)."""
    tgt = bit_view_dtype(x.dtype)
    if x.dtype == tgt:
        return x
    return jax.lax.bitcast_convert_type(x, tgt)


def _pack_2d(x: jax.Array) -> Tuple[jax.Array, int]:
    """Flatten + zero-pad to (rows, LANES). Returns (packed, orig_numel)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = max(1, -(-n // LANES))
    pad = rows * LANES - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(rows, LANES), n


def _block_rows(rows: int) -> int:
    """Largest power-of-two block <= DEFAULT that divides rows (grid evenness)."""
    b = min(_bitx.DEFAULT_BLOCK_ROWS, rows)
    while rows % b:
        b //= 2
    return max(b, 1)


# ---------------------------------------------------------------------------
# BitX encode / decode
# ---------------------------------------------------------------------------

def bitx_encode_planes(base: jax.Array, ft: jax.Array, *, use_pallas: bool = True) -> List[jax.Array]:
    """XOR-delta byte planes (MSB first) of ``ft`` against ``base``.

    Accepts float or bit-view arrays of identical shape/dtype; returns flat
    uint8 planes of length ``numel(base)``.
    """
    a = to_bit_view(jnp.asarray(base))
    b = to_bit_view(jnp.asarray(ft))
    assert a.shape == b.shape and a.dtype == b.dtype, (a.shape, b.shape, a.dtype, b.dtype)
    a2, n = _pack_2d(a)
    b2, _ = _pack_2d(b)
    if use_pallas:
        planes = _bitx.xor_split_2d(a2, b2, block_rows=_block_rows(a2.shape[0]), interpret=_interpret())
    else:
        planes = _ref.xor_split_planes(a2, b2)
    return [p.reshape(-1)[:n] for p in planes]


def bitx_decode_planes(planes: Sequence[jax.Array], base: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """Inverse of :func:`bitx_encode_planes`; returns the bit view of ``ft``
    with the same shape as ``base``."""
    a = to_bit_view(jnp.asarray(base))
    a2, n = _pack_2d(a)
    rows = a2.shape[0]
    padded: List[jax.Array] = []
    for p in planes:
        p = jnp.asarray(p).reshape(-1)
        pad = rows * LANES - p.shape[0]
        if pad:
            p = jnp.concatenate([p, jnp.zeros((pad,), p.dtype)])
        padded.append(p.reshape(rows, LANES))
    if use_pallas:
        out = _bitx.merge_xor_2d(padded, a2, block_rows=_block_rows(rows), interpret=_interpret())
    else:
        out = _ref.merge_planes_xor(padded, a2)
    return out.reshape(-1)[:n].reshape(a.shape)


# ---------------------------------------------------------------------------
# ZipNN byte planes (single model, no base)
# ---------------------------------------------------------------------------

def zipnn_split_planes(x: jax.Array, *, use_pallas: bool = True) -> List[jax.Array]:
    a = to_bit_view(jnp.asarray(x))
    a2, n = _pack_2d(a)
    if use_pallas:
        planes = _bp.split_2d(a2, block_rows=_block_rows(a2.shape[0]), interpret=_interpret())
    else:
        planes = _ref.byte_split(a2)
    return [p.reshape(-1)[:n] for p in planes]


def zipnn_merge_planes(planes: Sequence[jax.Array], dtype, shape, *, use_pallas: bool = True) -> jax.Array:
    dtype = bit_view_dtype(dtype)
    numel = 1
    for s in shape:
        numel *= s
    rows = max(1, -(-numel // LANES))
    padded: List[jax.Array] = []
    for p in planes:
        p = jnp.asarray(p).reshape(-1)
        pad = rows * LANES - p.shape[0]
        if pad:
            p = jnp.concatenate([p, jnp.zeros((pad,), p.dtype)])
        padded.append(p.reshape(rows, LANES))
    if use_pallas:
        out = _bp.merge_2d(padded, dtype, block_rows=_block_rows(rows), interpret=_interpret())
    else:
        out = _ref.byte_merge(padded, dtype)
    return out.reshape(-1)[:numel].reshape(shape)


# ---------------------------------------------------------------------------
# Bit distance
# ---------------------------------------------------------------------------

def hamming_total(a: jax.Array, b: jax.Array, *, use_pallas: bool = True) -> int:
    """Total differing bits between two same-shape arrays (exact, uint64-safe)."""
    av = to_bit_view(jnp.asarray(a))
    bv = to_bit_view(jnp.asarray(b))
    assert av.shape == bv.shape and av.dtype == bv.dtype
    a2, _ = _pack_2d(av)
    b2, _ = _pack_2d(bv)  # identical zero padding cancels in XOR
    if use_pallas:
        partials = _ham.hamming_partials_2d(
            a2, b2, block_rows=_block_rows(a2.shape[0]), interpret=_interpret()
        )
    else:
        partials = _ref.hamming_row_partials(a2, b2)
    return int(np.asarray(partials).astype(np.uint64).sum())


def bit_distance(a: jax.Array, b: jax.Array, *, use_pallas: bool = True) -> float:
    """Paper Eq. 1: mean differing bits per element."""
    n = int(np.prod(a.shape)) if hasattr(a, "shape") else int(np.asarray(a).size)
    total = hamming_total(a, b, use_pallas=use_pallas)
    return float(total) / float(max(n, 1))
