"""Pallas TPU kernels for the zLLM storage layer (+ beyond-paper compute).

Storage-path kernels (the paper's hot loops, DESIGN.md §3):
  bitx_xor.py     — fused XOR + byte-plane split/merge (BitX encode/decode)
  hamming.py      — fused XOR + popcount + two-stage reduce (bit distance)
  byte_planes.py  — ZipNN byte-plane shuffle (the no-family fallback)

Beyond-paper compute kernel (EXPERIMENTS.md §Perf):
  flash_attention.py — fwd flash attention, VMEM-resident score blocks

Each kernel pairs with a pure-jnp oracle in ``ref.py``; ``ops.py`` is the
public jit'd API. On non-TPU backends kernels run in interpret mode; tests
sweep shapes/dtypes asserting exact (bit ops) or tight-tolerance (attention)
agreement with the oracles.

These kernels are LIVE in the storage pipeline: the jax ``ArrayBackend``
(``repro.core.bitx.JaxBackend``, selected via ``ZLLMStore(backend="jax")``
or ``"auto"`` on accelerator hosts) routes the pipeline's encode stage and
decode fan-out through ``ops.bitx_encode_planes`` / ``bitx_decode_planes`` /
``zipnn_split_planes`` / ``zipnn_merge_planes``, concatenating same-width
tensors so each dtype bucket costs one fused launch. Containers stay
bit-identical to the numpy host path (test-enforced), so the kernels are a
pure throughput substitution.
"""
