"""Pallas TPU kernels for the zLLM storage layer (+ beyond-paper compute).

Storage-path kernels (the paper's hot loops; pipeline context in
docs/ARCHITECTURE.md):
  bitx_xor.py     — fused XOR + byte-plane split/merge (BitX encode/decode)
  hamming.py      — fused XOR + popcount + two-stage reduce (bit distance)
  byte_planes.py  — ZipNN byte-plane shuffle (the no-family fallback)

Beyond-paper compute kernel:
  flash_attention.py — fwd flash attention, VMEM-resident score blocks

Each kernel pairs with a pure-jnp oracle in ``ref.py``; ``ops.py`` is the
public jit'd API. On non-TPU backends kernels run in interpret mode; tests
sweep shapes/dtypes asserting exact (bit ops) or tight-tolerance (attention)
agreement with the oracles.

These kernels are LIVE in the storage pipeline, reached through two layers
of indirection rather than called directly: the pipeline dispatches every
tensor to a codec via the registry in ``repro.core.codecs``
(``register_codec``; six lanes — bitx / bitxq / zipnn / raw / stored /
dedup), and each codec's encode/decode runs on the session's
``ArrayBackend``. The jax backend (``repro.core.bitx.JaxBackend``, selected
via ``ZLLMStore(backend="jax")`` or ``"auto"`` on accelerator hosts)
implements the backend primitives — ``xor_delta_planes``, ``byte_planes``,
``merge_planes_xor`` — on ``ops.bitx_encode_planes`` / ``bitx_decode_planes``
/ ``zipnn_split_planes`` / ``zipnn_merge_planes``, and the device-batched
hot path concatenates same-width tensors so each dtype bucket costs one
fused launch (the ``bitxq`` lane deliberately stays on the host path for
cross-backend determinism). Containers stay bit-identical to the numpy host
path (test-enforced), so the kernels are a pure throughput substitution.
"""
