"""Pallas TPU kernels for BitX encode/decode (paper §4.3).

Encode: ``delta = base ^ ft`` fused with a byte-plane split of the delta.
Decode: merge byte planes back into the delta and XOR with the base.

TPU adaptation (DESIGN.md §3): the paper's C++ implementation streams bytes on
a CPU. On TPU the tensors are already resident in HBM (e.g. when a checkpoint
is being taken), so we tile them through VMEM and do XOR + shift/mask plane
extraction on the VPU. Plane extraction is a pure lane-local shift — no
gather/scatter — so the kernel is memory-bound by design: one HBM read per
input, one write per plane. Blocks are (block_rows, 1024): the lane dim is a
multiple of both the VPU lane width (128) and the dtype packing, and a
256×1024 uint16 tile is 512 KiB — three such tiles (two in, planes out) sit
comfortably in the ~16 MiB of VMEM of a v5e core.

All kernels operate on 2D unsigned-int bit views; ``ops.py`` owns the
flatten/pad/bitcast plumbing and the interpret-mode fallback used for CPU
validation.
"""

from __future__ import annotations

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "xor_2d",
    "xor_split_2d",
    "merge_xor_2d",
    "DEFAULT_BLOCK_ROWS",
    "LANES",
]

LANES = 1024  # second-minor tile dim; multiple of the 128-lane VPU width
DEFAULT_BLOCK_ROWS = 256


def _xor_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.bitwise_xor(a_ref[...], b_ref[...])


def _xor_split_kernel(a_ref, b_ref, *plane_refs):
    """XOR + byte-plane split, MSB plane first."""
    delta = jnp.bitwise_xor(a_ref[...], b_ref[...])
    nb = len(plane_refs)
    for i, p_ref in enumerate(plane_refs):
        k = nb - 1 - i
        p_ref[...] = jnp.right_shift(delta, jnp.array(8 * k, delta.dtype)).astype(jnp.uint8)


def _merge_xor_kernel(base_ref, *refs):
    """planes (MSB first) + base -> ft bits. Last ref is the output."""
    plane_refs, o_ref = refs[:-1], refs[-1]
    dtype = base_ref.dtype
    nb = len(plane_refs)
    delta = jnp.zeros(base_ref.shape, dtype)
    for i, p_ref in enumerate(plane_refs):
        k = nb - 1 - i
        delta = jnp.bitwise_or(
            delta, jnp.left_shift(p_ref[...].astype(dtype), jnp.array(8 * k, dtype))
        )
    o_ref[...] = jnp.bitwise_xor(delta, base_ref[...])


def _row_blockspec(block_rows: int, cols: int) -> pl.BlockSpec:
    return pl.BlockSpec((block_rows, cols), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def xor_2d(
    a: jax.Array,
    b: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Element-wise XOR over a 2D (rows, LANES-multiple) bit view."""
    rows, cols = a.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    spec = _row_blockspec(block_rows, cols)
    return pl.pallas_call(
        _xor_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        in_specs=[spec, spec],
        out_specs=spec,
        grid=grid,
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def xor_split_2d(
    base: jax.Array,
    ft: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> List[jax.Array]:
    """Fused BitX encode over a 2D bit view. Returns byte planes, MSB first."""
    rows, cols = base.shape
    nb = jnp.dtype(base.dtype).itemsize
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    spec = _row_blockspec(block_rows, cols)
    out = pl.pallas_call(
        _xor_split_kernel,
        out_shape=[jax.ShapeDtypeStruct(base.shape, jnp.uint8) for _ in range(nb)],
        in_specs=[spec, spec],
        out_specs=[spec] * nb,
        grid=grid,
        interpret=interpret,
    )(base, ft)
    return list(out)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def merge_xor_2d(
    planes: Sequence[jax.Array],
    base: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Fused BitX decode over a 2D bit view: planes (MSB first) + base -> ft."""
    rows, cols = base.shape
    nb = jnp.dtype(base.dtype).itemsize
    assert len(planes) == nb, (len(planes), nb)
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    spec = _row_blockspec(block_rows, cols)
    return pl.pallas_call(
        _merge_xor_kernel,
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        in_specs=[spec] * (1 + nb),
        out_specs=spec,
        grid=grid,
        interpret=interpret,
    )(base, *planes)
