"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel's test sweeps
shapes/dtypes and asserts bit-exact agreement against these functions.
Everything here is lossless bit manipulation, so tolerance is exact equality.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "mha_reference",
    "xor_bits",
    "xor_split_planes",
    "merge_planes_xor",
    "hamming_total",
    "byte_split",
    "byte_merge",
]

_UINT_BYTES = {jnp.uint16.dtype: 2, jnp.uint32.dtype: 4, jnp.uint8.dtype: 1, jnp.uint64.dtype: 8}


def _nbytes(dtype) -> int:
    d = jnp.dtype(dtype)
    if d not in _UINT_BYTES:
        raise ValueError(f"expected unsigned int bit-view dtype, got {d}")
    return _UINT_BYTES[d]


def xor_bits(a: jax.Array, b: jax.Array) -> jax.Array:
    """Element-wise XOR of two identically-shaped unsigned-int bit views."""
    assert a.shape == b.shape and a.dtype == b.dtype
    return jnp.bitwise_xor(a, b)


def byte_split(x: jax.Array) -> List[jax.Array]:
    """Split an unsigned-int array into per-byte planes, most significant first.

    For BF16 bit views (uint16) this yields [sign+exp7, exp1+mantissa7] — the
    ZipNN grouping. For FP32 (uint32): 4 planes. Output planes are uint8 arrays
    of the same shape as ``x``.
    """
    nb = _nbytes(x.dtype)
    planes = []
    for k in range(nb - 1, -1, -1):  # MSB plane first
        planes.append(jnp.right_shift(x, jnp.array(8 * k, x.dtype)).astype(jnp.uint8))
    return planes


def byte_merge(planes: List[jax.Array], dtype) -> jax.Array:
    """Inverse of :func:`byte_split`."""
    dtype = jnp.dtype(dtype)
    nb = _nbytes(dtype)
    assert len(planes) == nb
    out = jnp.zeros(planes[0].shape, dtype)
    for i, p in enumerate(planes):
        k = nb - 1 - i
        out = jnp.bitwise_or(out, jnp.left_shift(p.astype(dtype), jnp.array(8 * k, dtype)))
    return out


def xor_split_planes(base: jax.Array, ft: jax.Array) -> List[jax.Array]:
    """Fused BitX encode: XOR two bit views, split the delta into byte planes.

    The hi plane (sign/exponent/upper-mantissa for BF16) is near-all-zero for
    same-family model pairs (paper Fig. 5), which is what makes the downstream
    entropy stage effective.
    """
    return byte_split(xor_bits(base, ft))


def merge_planes_xor(planes: List[jax.Array], base: jax.Array) -> jax.Array:
    """Fused BitX decode: merge byte planes into the XOR delta, XOR with base."""
    delta = byte_merge(planes, base.dtype)
    return jnp.bitwise_xor(delta, base)


def hamming_row_partials(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-row popcount partial sums (uint32) over 2D bit views.

    A row of up to 2²⁶ bit positions stays far below uint32 overflow; the
    caller finishes the reduction in uint64 on the host (``ops.hamming_total``).
    """
    assert a.shape == b.shape and a.dtype == b.dtype
    pc = jax.lax.population_count(jnp.bitwise_xor(a, b))
    return jnp.sum(pc.astype(jnp.uint32), axis=-1, dtype=jnp.uint32)


def hamming_total(a: jax.Array, b: jax.Array) -> jax.Array:
    """Total number of differing bits between two bit views (uint32 scalar).

    Oracle for test-scale inputs (< 2³² differing bits). The production path
    (``ops.hamming_total``) sums block partials in uint64 on the host, because
    embedding-scale tensors can exceed uint32.
    """
    assert a.shape == b.shape and a.dtype == b.dtype
    pc = jax.lax.population_count(jnp.bitwise_xor(a, b))
    return jnp.sum(pc.astype(jnp.uint32), dtype=jnp.uint32)


def mha_reference(q, k, v, *, causal=True, window=0):
    """Dense masked softmax attention oracle for the flash kernel.

    q: (B, Sq, H, D); k, v: (B, Sk, H, D). fp32 softmax, output in q.dtype.
    """
    import jax.numpy as jnp
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kj <= qi
    if window:
        mask &= kj > qi - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
