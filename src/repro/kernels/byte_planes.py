"""Pallas TPU kernels for ZipNN-style byte-plane shuffling (paper §4.4.3 fallback).

ZipNN groups the bytes of floating-point words so that the highly-redundant
fields (sign+exponent) form contiguous streams for the entropy coder. For BF16
bit views (uint16) that is two planes: [sign|exp7] and [exp_lsb|mantissa7];
for FP32 (uint32), four planes. Unlike BitX these kernels take a *single*
model (no base): they are the no-family fallback compressor and the ZipNN
baseline used in the evaluation.

Same tiling story as ``bitx_xor.py``: lane-local shifts/masks on the VPU,
(block_rows, 1024) VMEM tiles, memory-bound by construction.
"""

from __future__ import annotations

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitx_xor import DEFAULT_BLOCK_ROWS

__all__ = ["split_2d", "merge_2d"]


def _split_kernel(x_ref, *plane_refs):
    x = x_ref[...]
    nb = len(plane_refs)
    for i, p_ref in enumerate(plane_refs):
        k = nb - 1 - i  # MSB plane first
        p_ref[...] = jnp.right_shift(x, jnp.array(8 * k, x.dtype)).astype(jnp.uint8)


def _merge_kernel(*refs):
    plane_refs, o_ref = refs[:-1], refs[-1]
    dtype = o_ref.dtype
    nb = len(plane_refs)
    out = jnp.zeros(o_ref.shape, dtype)
    for i, p_ref in enumerate(plane_refs):
        k = nb - 1 - i
        out = jnp.bitwise_or(
            out, jnp.left_shift(p_ref[...].astype(dtype), jnp.array(8 * k, dtype))
        )
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def split_2d(
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> List[jax.Array]:
    """Split a 2D bit view into uint8 byte planes, MSB first."""
    rows, cols = x.shape
    nb = jnp.dtype(x.dtype).itemsize
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    out = pl.pallas_call(
        _split_kernel,
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.uint8) for _ in range(nb)],
        in_specs=[spec],
        out_specs=[spec] * nb,
        grid=grid,
        interpret=interpret,
    )(x)
    return list(out)


@functools.partial(jax.jit, static_argnames=("dtype", "block_rows", "interpret"))
def merge_2d(
    planes: Sequence[jax.Array],
    dtype,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Inverse of :func:`split_2d`."""
    dtype = jnp.dtype(dtype)
    nb = dtype.itemsize
    assert len(planes) == nb, (len(planes), nb)
    rows, cols = planes[0].shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _merge_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), dtype),
        in_specs=[spec] * nb,
        out_specs=spec,
        grid=grid,
        interpret=interpret,
    )(*planes)
