"""Bit distance (paper Eq. 1) + Monte-Carlo clustering-threshold calibration (§4.2, A.0.1).

``bit_distance_arrays`` / ``bit_distance_files`` implement the metric on
aligned bit views (host numpy path for mmap'd files, jax/Pallas path for
device-resident tensors). ``expected_bit_distance_mc`` reproduces the paper's
Monte-Carlo estimate of E[D(w, w+δ)] under w ~ N(0, σw²), δ ~ N(0, σΔ²), which
yields the within-family range [~3.5, 6] bits for BF16 and motivates the
threshold of 4 (Fig. 11/12).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "bit_distance_arrays",
    "hamming_total_arrays",
    "bit_distance_files",
    "shape_signature",
    "expected_bit_distance_mc",
    "calibration_heatmap",
    "DEFAULT_THRESHOLD",
]

# Paper §4.2: threshold 4 gives 93.5% family classification accuracy.
DEFAULT_THRESHOLD = 4.0


def _bit_view(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind == "u":
        return arr
    return arr.view(f"<u{arr.dtype.itemsize}")


def hamming_total_arrays(a: np.ndarray, b: np.ndarray) -> int:
    """Total differing bits between two same-shape arrays (numpy host path)."""
    av = _bit_view(np.ascontiguousarray(a)).reshape(-1)
    bv = _bit_view(np.ascontiguousarray(b)).reshape(-1)
    assert av.shape == bv.shape and av.dtype == bv.dtype
    delta = np.bitwise_xor(av, bv)
    # np.bitwise_count (numpy>=2) is a vectorized popcount.
    return int(np.bitwise_count(delta).astype(np.uint64).sum())


def bit_distance_arrays(a: np.ndarray, b: np.ndarray) -> float:
    """Paper Eq. 1 over two aligned arrays: mean differing bits per element."""
    n = int(np.prod(a.shape)) if a.shape else a.size
    if n == 0:
        return 0.0
    return hamming_total_arrays(a, b) / n


def shape_signature(infos) -> Tuple:
    """Order-sensitive (name-free) signature of a model's tensor shapes+dtypes.

    §4.2: models with different tensor shapes are immediately cross-family —
    the cheap prefilter before any bit distance is computed.
    """
    return tuple((ti.dtype_str, ti.shape) for ti in infos)


def bit_distance_files(
    path_a: str,
    path_b: str,
    sample_elems_per_tensor: Optional[int] = 262_144,
) -> float:
    """Bit distance between two safetensors files, aligned by serialization
    order. ``sample_elems_per_tensor`` caps per-tensor work (prefix sample) —
    the paper's matching step needs "fewer than five comparisons" per model, and
    a prefix of each tensor is an unbiased-enough estimator for thresholding
    (validated in tests against the full scan).
    """
    from repro.formats.safetensors import SafetensorsFile

    with SafetensorsFile(path_a) as fa, SafetensorsFile(path_b) as fb:
        if shape_signature(fa.infos) != shape_signature(fb.infos):
            return float("inf")  # structurally different => cross-family
        total_bits = 0
        total_elems = 0
        for ta, tb in zip(fa.infos, fb.infos):
            va = fa.tensor(ta.name).reshape(-1)
            vb = fb.tensor(tb.name).reshape(-1)
            if sample_elems_per_tensor and va.size > sample_elems_per_tensor:
                va = va[:sample_elems_per_tensor]
                vb = vb[:sample_elems_per_tensor]
            total_bits += hamming_total_arrays(va, vb)
            total_elems += va.size
        return total_bits / max(total_elems, 1)


# ---------------------------------------------------------------------------
# Monte-Carlo threshold calibration (paper §4.2, Appendix A.0.1)
# ---------------------------------------------------------------------------

def expected_bit_distance_mc(
    sigma_w: float,
    sigma_delta: float,
    n: int = 100_000,
    dtype: str = "bfloat16",
    seed: int = 0,
) -> float:
    """Monte-Carlo estimate of E[D(w, w+δ)] (paper's N=100,000 default).

    Bit distance is discontinuous in the float value (ULP boundaries), so the
    expectation is sampled exactly as the paper does: draw w and δ in fp32,
    round both w and w+δ to the target dtype, popcount the XOR.
    """
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    kw, kd = jax.random.split(key)
    w = jax.random.normal(kw, (n,), jnp.float32) * sigma_w
    d = jax.random.normal(kd, (n,), jnp.float32) * sigma_delta
    wt = w.astype(dtype)
    ft = (w + d).astype(dtype)
    bits = jax.lax.population_count(
        jnp.bitwise_xor(
            jax.lax.bitcast_convert_type(wt, jnp.uint16 if jnp.dtype(dtype).itemsize == 2 else jnp.uint32),
            jax.lax.bitcast_convert_type(ft, jnp.uint16 if jnp.dtype(dtype).itemsize == 2 else jnp.uint32),
        )
    )
    return float(jnp.mean(bits.astype(jnp.float32)))


@dataclass
class CalibrationResult:
    sigma_w_grid: List[float]
    sigma_delta_grid: List[float]
    heatmap: np.ndarray  # E[D] per (sigma_w, sigma_delta)
    within_family_range: Tuple[float, float]

    def recommended_threshold(self, cross_family_floor: float = 6.0) -> float:
        """Paper A.0.1: clip the in-family upper bound at the near-cross-family
        bit distance (~4 for Llama-3 vs 3.1) rather than the generic floor."""
        return min(DEFAULT_THRESHOLD, cross_family_floor)


def calibration_heatmap(
    sigma_w_grid: Sequence[float] = (0.01, 0.015, 0.02, 0.03, 0.04, 0.05),
    sigma_delta_grid: Sequence[float] = (0.0005, 0.001, 0.002, 0.005, 0.01, 0.02),
    n: int = 100_000,
    dtype: str = "bfloat16",
) -> CalibrationResult:
    """Reproduces Fig. 11: expected-bit-distance heatmap over (σw, σΔ)."""
    hm = np.zeros((len(sigma_w_grid), len(sigma_delta_grid)), np.float64)
    for i, sw in enumerate(sigma_w_grid):
        for j, sd in enumerate(sigma_delta_grid):
            hm[i, j] = expected_bit_distance_mc(sw, sd, n=n, dtype=dtype, seed=i * 31 + j)
    # within-family empirical band (paper: σw∈[0.015,0.05], σΔ∈[0,0.02])
    band = hm[np.ix_(
        [i for i, s in enumerate(sigma_w_grid) if 0.015 <= s <= 0.05],
        [j for j, s in enumerate(sigma_delta_grid) if s <= 0.02],
    )]
    rng = (float(band.min()), float(band.max())) if band.size else (float(hm.min()), float(hm.max()))
    return CalibrationResult(list(sigma_w_grid), list(sigma_delta_grid), hm, rng)
