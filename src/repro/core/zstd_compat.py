"""Entropy-coder backend shim: `zstandard` when available, zlib otherwise.

The paper's engine uses zstd for the per-plane entropy stage. Some
deployment containers (including this one) ship without the `zstandard`
wheel, so every storage module imports the compressor through this shim
instead of `import zstandard as zstd` directly:

    from repro.core import zstd_compat as zstd

* With `zstandard` installed the shim re-exports the real
  ``ZstdCompressor`` / ``ZstdDecompressor`` untouched (``BACKEND == "zstd"``).
* Without it, a zlib-backed stand-in implements the same one-shot
  ``compress(data)`` / ``decompress(frame)`` subset the storage layer uses.
  zstd levels (1..22) are mapped onto zlib levels (1..9).

Frames from the two backends are NOT interchangeable, so `.bitx`
containers record the backend that wrote them (``BitXWriter`` stamps the
top-level ``"backend"`` header key) and ``BitXReader`` refuses to decode
a container written by a backend other than the active one.

Thread-safety contract (identical for both backends): compressor and
decompressor *objects* must not be shared across threads mid-operation —
the storage layer gives each worker thread its own contexts
(`repro.core.codecs.CodecRuntime` holds them in thread-local storage and
asserts owner-thread on every use). The module-level classes themselves
are safe to construct from any thread.
"""

from __future__ import annotations

import zlib

__all__ = ["ZstdCompressor", "ZstdDecompressor", "BACKEND", "HAVE_ZSTD"]

try:  # pragma: no cover - depends on container contents
    import zstandard as _zstd

    HAVE_ZSTD = True
    BACKEND = "zstd"
    ZstdCompressor = _zstd.ZstdCompressor
    ZstdDecompressor = _zstd.ZstdDecompressor
except ImportError:  # zlib fallback
    HAVE_ZSTD = False
    BACKEND = "zlib"

    def _map_level(level: int) -> int:
        """Map a zstd level (1..22, default 3) onto zlib's 1..9 range."""
        if level <= 0:
            return 6  # zlib default; zstd level 0 means "default" too
        return max(1, min(9, round(level * 9 / 22) or 1))

    class ZstdCompressor:
        """zlib-backed stand-in for ``zstandard.ZstdCompressor``.

        Accepts (and records) the ``threads`` argument for API parity;
        zlib has no internal threading, so parallelism comes from the
        storage engine's worker pool instead.
        """

        def __init__(self, level: int = 3, threads: int = 0, **_kw):
            self.level = level
            self.threads = threads
            self._zlevel = _map_level(level)

        def compress(self, data) -> bytes:
            return zlib.compress(data, self._zlevel)

    class ZstdDecompressor:
        """zlib-backed stand-in for ``zstandard.ZstdDecompressor``."""

        def decompress(self, frame, max_output_size: int = 0) -> bytes:
            return zlib.decompress(frame)
