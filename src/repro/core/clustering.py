"""LLM family clustering via bit distance (paper §3.4.3, §4.2, Fig. 4).

``FamilyRegistry`` holds the standalone-coded base models; fine-tuned uploads
are matched by (1) shape-signature prefilter — different tensor shapes ⇒
cross-family immediately — then (2) sampled bit distance against the (few)
remaining candidates, thresholded at 4 bits/element (93.5% accuracy, paper
A.0.1). ``cluster_models`` builds the Fig.-4 similarity graph and returns its
connected components.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bitdistance import (DEFAULT_THRESHOLD, bit_distance_arrays,
                                    hamming_total_arrays, shape_signature)
from repro.formats.safetensors import SafetensorsFile

__all__ = ["FamilyRegistry", "cluster_models", "pairwise_bit_distances",
           "score_family_clustering"]


def _sampled_distance(fa: SafetensorsFile, fb: SafetensorsFile,
                      sample_elems: int = 65536) -> float:
    total_bits = 0
    total_elems = 0
    for ta, tb in zip(fa.infos, fb.infos):
        va = fa.tensor(ta.name).reshape(-1)
        vb = fb.tensor(tb.name).reshape(-1)
        if sample_elems and va.size > sample_elems:
            va, vb = va[:sample_elems], vb[:sample_elems]
        total_bits += hamming_total_arrays(va, vb)
        total_elems += va.size
    return total_bits / max(total_elems, 1)


@dataclass
class FamilyRegistry:
    """Registered base models, keyed by shape signature for the prefilter."""

    threshold: float = DEFAULT_THRESHOLD
    sample_elems: int = 65536
    by_sig: Dict[Tuple, List[Tuple[str, str]]] = field(default_factory=dict)  # sig -> [(base_id, path)]
    comparisons: int = 0

    def register(self, base_id: str, path: str) -> None:
        with SafetensorsFile(path) as sf:
            sig = shape_signature(sf.infos)
        self.by_sig.setdefault(sig, []).append((base_id, path))

    def unregister(self, base_id: str) -> int:
        """Remove every registration for ``base_id`` (repo deletion). Returns
        the number of entries dropped; empty signature buckets are pruned."""
        dropped = 0
        for sig in list(self.by_sig):
            kept = [(bid, p) for bid, p in self.by_sig[sig] if bid != base_id]
            dropped += len(self.by_sig[sig]) - len(kept)
            if kept:
                self.by_sig[sig] = kept
            else:
                del self.by_sig[sig]
        return dropped

    def candidates(self, path: str) -> List[Tuple[str, str]]:
        with SafetensorsFile(path) as sf:
            sig = shape_signature(sf.infos)
        return self.by_sig.get(sig, [])

    def match(self, path: str) -> Optional[Tuple[str, float]]:
        """Closest registered base under the threshold, or None."""
        cands = self.candidates(path)
        if not cands:
            return None
        best: Optional[Tuple[str, float]] = None
        with SafetensorsFile(path) as sf:
            for base_id, base_path in cands:
                with SafetensorsFile(base_path) as bf:
                    d = _sampled_distance(sf, bf, self.sample_elems)
                self.comparisons += 1
                if best is None or d < best[1]:
                    best = (base_id, d)
        if best is not None and best[1] <= self.threshold:
            return best
        return None


def pairwise_bit_distances(paths: Sequence[str], sample_elems: int = 65536) -> np.ndarray:
    """Dense pairwise distance matrix (inf for shape-incompatible pairs)."""
    n = len(paths)
    D = np.full((n, n), np.inf)
    np.fill_diagonal(D, 0.0)
    sigs = []
    for p in paths:
        with SafetensorsFile(p) as sf:
            sigs.append(shape_signature(sf.infos))
    for i in range(n):
        for j in range(i + 1, n):
            if sigs[i] != sigs[j]:
                continue
            with SafetensorsFile(paths[i]) as fa, SafetensorsFile(paths[j]) as fb:
                D[i, j] = D[j, i] = _sampled_distance(fa, fb, sample_elems)
    return D


def cluster_models(paths: Sequence[str], threshold: float = DEFAULT_THRESHOLD,
                   sample_elems: int = 65536) -> List[List[int]]:
    """Connected components of the bit-distance similarity graph (Fig. 4)."""
    D = pairwise_bit_distances(paths, sample_elems)
    n = len(paths)
    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for i in range(n):
        for j in range(i + 1, n):
            if D[i, j] <= threshold:
                parent[find(i)] = find(j)
    comps: Dict[int, List[int]] = {}
    for i in range(n):
        comps.setdefault(find(i), []).append(i)
    return sorted(comps.values(), key=len, reverse=True)


def score_family_clustering(paths: Sequence[str], true_labels: Sequence[str],
                            threshold: float = DEFAULT_THRESHOLD,
                            sample_elems: int = 65536) -> Dict[str, float]:
    """Score :func:`cluster_models` against ground-truth family labels.

    Pairwise counting — the standard external clustering measure: every
    unordered model pair is a trial; a true positive is a same-family pair
    the clustering put in one component. Returns precision / recall / F1 /
    Rand-accuracy over all pairs, plus the trial counts. This is what turns
    the paper's "93.5% clustering accuracy" (§A.0.1) claim into a scored,
    CI-gated bench metric (``zllm.cluster.family_f1``) on the synthetic
    hub's emitted ground truth (``families.json``).
    """
    if len(paths) != len(true_labels):
        raise ValueError(f"{len(paths)} paths but {len(true_labels)} labels")
    clusters = cluster_models(paths, threshold, sample_elems)
    pred = [0] * len(paths)
    for ci, comp in enumerate(clusters):
        for i in comp:
            pred[i] = ci
    tp = fp = fn = tn = 0
    n = len(paths)
    for i in range(n):
        for j in range(i + 1, n):
            same_true = true_labels[i] == true_labels[j]
            same_pred = pred[i] == pred[j]
            if same_true and same_pred:
                tp += 1
            elif same_pred:
                fp += 1
            elif same_true:
                fn += 1
            else:
                tn += 1
    n_pairs = tp + fp + fn + tn
    precision = tp / (tp + fp) if tp + fp else 1.0
    recall = tp / (tp + fn) if tp + fn else 1.0
    f1 = (2 * precision * recall / (precision + recall)
          if precision + recall else 0.0)
    return {"precision": round(precision, 4), "recall": round(recall, 4),
            "f1": round(f1, 4),
            "accuracy": round((tp + tn) / n_pairs, 4) if n_pairs else 1.0,
            "n_models": n, "n_pairs": n_pairs,
            "n_clusters": len(clusters)}
