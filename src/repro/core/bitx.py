"""BitX lossless delta compression (paper §4.3).

Encode: align the floats of a fine-tuned tensor with its base tensor in
serialization order, bitcast both to unsigned words, XOR, split the delta into
byte planes (MSB plane ≈ all zeros within a family, Fig. 5), entropy-code each
plane with zstd. Decode is the exact inverse; the pipeline verifies bit-exact
reconstruction.

Two compute paths, tested bit-identical:

* ``backend="numpy"`` — host path for mmap'd safetensors ingestion (the
  evaluation/throughput path, mirroring the paper's C++ engine);
* ``backend="jax"`` — the Pallas kernels (``repro.kernels``), the TPU
  deployment path (encode checkpoints while they are still in HBM).

Container format (``.bitx``): a 16-byte magic+version, a JSON header
describing per-tensor records, then concatenated zstd frames. Per-tensor
records keep the base tensor's content hash so retrieval can fetch the base
from the CAS pool (§4.4.4).
"""

from __future__ import annotations

import io
import json
import mmap
import os
import struct
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import zstd_compat as zstd

__all__ = [
    "BitXCodec",
    "TensorRecord",
    "BitXWriter",
    "BitXReader",
    "TMP_SUFFIX",
    "xor_delta_planes_np",
    "merge_planes_xor_np",
    "byte_planes_np",
]

MAGIC = b"BITX0001"
DEFAULT_ZSTD_LEVEL = 3

# Containers are written to ``<path>.part`` and atomically renamed into
# place, so a crash mid-write can never leave a torn file at a path the
# index might reference. Leftover ``.part`` files are crash debris; the
# store's fsck orphan scan recognizes the suffix and deletes them under
# repair (they are never referenced by the version graph).
TMP_SUFFIX = ".part"


def _bit_view_np(arr: np.ndarray) -> np.ndarray:
    """View a numpy array as unsigned words of the same width (no copy)."""
    if arr.dtype.kind == "u":
        return arr
    if arr.dtype.kind in ("f", "i"):
        return arr.view(f"<u{arr.dtype.itemsize}")
    raise ValueError(f"unsupported dtype {arr.dtype}")


def xor_delta_planes_np(base: np.ndarray, ft: np.ndarray) -> List[np.ndarray]:
    """Numpy path: XOR bit views and split into byte planes (MSB first).

    The plane split is a strided view of the little-endian byte buffer, so the
    whole encode is two passes over memory (XOR, then per-plane copy).
    """
    a = _bit_view_np(np.ascontiguousarray(base)).reshape(-1)
    b = _bit_view_np(np.ascontiguousarray(ft)).reshape(-1)
    assert a.shape == b.shape and a.dtype == b.dtype, (a.shape, b.shape, a.dtype, b.dtype)
    delta = np.bitwise_xor(a, b)
    nb = delta.dtype.itemsize
    raw = delta.view(np.uint8).reshape(-1, nb)
    # little-endian: byte column nb-1 is the MSB
    return [np.ascontiguousarray(raw[:, nb - 1 - i]) for i in range(nb)]


def byte_planes_np(x: np.ndarray) -> List[np.ndarray]:
    """MSB-first byte planes of ``x``'s bit view (the ZipNN split). Shared by
    ``BitXCodec.encode_planes`` and the process-pool entropy backend, so the
    two paths split planes identically and stay bit-compatible."""
    v = _bit_view_np(np.ascontiguousarray(x)).reshape(-1)
    nb = v.dtype.itemsize
    raw = v.view(np.uint8).reshape(-1, nb)
    return [np.ascontiguousarray(raw[:, nb - 1 - i]) for i in range(nb)]


def merge_planes_xor_np(planes: Sequence[np.ndarray], base: np.ndarray) -> np.ndarray:
    """Inverse of :func:`xor_delta_planes_np`; returns the ft bit view shaped
    like ``base``."""
    a = _bit_view_np(np.ascontiguousarray(base))
    nb = a.dtype.itemsize
    assert len(planes) == nb
    n = a.size
    raw = np.empty((n, nb), np.uint8)
    for i, p in enumerate(planes):
        raw[:, nb - 1 - i] = p
    delta = raw.reshape(-1).view(a.dtype.str)
    return np.bitwise_xor(delta, a.reshape(-1)).reshape(a.shape)


@dataclass
class TensorRecord:
    """Header record for one tensor inside a .bitx container."""

    name: str
    dtype_str: str            # safetensors tag of the original tensor ("BF16", "F32", ...)
    shape: Tuple[int, ...]
    codec: str                # "bitx" | "zipnn" | "raw" | "stored" | "dedup"
    base_hash: Optional[str]  # CAS hash of the base tensor (bitx) / None
    self_hash: str            # CAS hash of this tensor's raw bytes (dedup + verify)
    plane_sizes: List[int] = field(default_factory=list)  # compressed bytes per plane
    raw_size: int = 0

    def to_json(self) -> Dict:
        return {
            "name": self.name,
            "dtype": self.dtype_str,
            "shape": list(self.shape),
            "codec": self.codec,
            "base_hash": self.base_hash,
            "self_hash": self.self_hash,
            "plane_sizes": self.plane_sizes,
            "raw_size": self.raw_size,
        }

    @staticmethod
    def from_json(d: Dict) -> "TensorRecord":
        return TensorRecord(
            name=d["name"],
            dtype_str=d["dtype"],
            shape=tuple(d["shape"]),
            codec=d["codec"],
            base_hash=d.get("base_hash"),
            self_hash=d["self_hash"],
            plane_sizes=list(d.get("plane_sizes", [])),
            raw_size=int(d.get("raw_size", 0)),
        )


class BitXCodec:
    """Per-tensor BitX / ZipNN / raw encode+decode with a zstd entropy stage.

    ``threads`` is forwarded to ``zstd.ZstdCompressor(threads=...)`` (zstd's
    internal frame-level multithreading; ignored by the zlib fallback).

    zstd compressor/decompressor *contexts* are not thread-safe, so a codec
    instance keeps its contexts in thread-local storage: the parallel ingest
    and retrieval engines share one ``BitXCodec`` across their worker pool and
    each worker lazily materializes its own pair of contexts. Frames are a
    pure function of (input bytes, level, threads), so per-worker contexts do
    not change the emitted bytes.
    """

    def __init__(self, level: int = DEFAULT_ZSTD_LEVEL, threads: int = 0):
        self.level = level
        self.threads = threads
        self._tls = threading.local()

    @property
    def _cctx(self):
        ctx = getattr(self._tls, "cctx", None)
        if ctx is None:
            ctx = self._tls.cctx = zstd.ZstdCompressor(level=self.level,
                                                       threads=self.threads)
        return ctx

    @property
    def _dctx(self):
        ctx = getattr(self._tls, "dctx", None)
        if ctx is None:
            ctx = self._tls.dctx = zstd.ZstdDecompressor()
        return ctx

    # -- BitX ---------------------------------------------------------------
    def encode_delta(self, base: np.ndarray, ft: np.ndarray) -> Tuple[List[bytes], int]:
        """Returns (compressed plane frames MSB-first, raw byte size)."""
        planes = xor_delta_planes_np(base, ft)
        frames = [self._cctx.compress(p.tobytes()) for p in planes]
        return frames, int(_bit_view_np(ft).nbytes)

    def decode_delta(
        self, frames: Sequence[bytes], base: np.ndarray
    ) -> np.ndarray:
        planes = [np.frombuffer(self._dctx.decompress(f), np.uint8) for f in frames]
        return merge_planes_xor_np(planes, base)

    # -- ZipNN fallback (no base available, §4.4.3) ---------------------------
    def encode_planes(self, x: np.ndarray) -> Tuple[List[bytes], int]:
        planes = byte_planes_np(x)
        frames = [self._cctx.compress(p.tobytes()) for p in planes]
        return frames, int(sum(p.nbytes for p in planes))

    def decode_planes(self, frames: Sequence[bytes], dtype_np: np.dtype, shape) -> np.ndarray:
        nb = np.dtype(dtype_np).itemsize
        assert len(frames) == nb
        n = int(np.prod(shape)) if len(shape) else 1
        raw = np.empty((n, nb), np.uint8)
        for i, f in enumerate(frames):
            raw[:, nb - 1 - i] = np.frombuffer(self._dctx.decompress(f), np.uint8)
        return raw.reshape(-1).view(np.dtype(dtype_np).str).reshape(shape)

    # -- raw zstd (non-float / last resort) ----------------------------------
    def encode_raw(self, data: bytes) -> bytes:
        return self._cctx.compress(data)

    def decode_raw(self, frame: bytes) -> bytes:
        return self._dctx.decompress(frame)

    # -- stored (verbatim) ----------------------------------------------------
    @staticmethod
    def choose_raw_codec(data: bytes, frame: bytes) -> Tuple[str, bytes]:
        """Entropy-stage decision for raw-kind tensors: keep the compressed
        frame only when it actually shrank the input; otherwise store the
        bytes VERBATIM under codec ``stored``. A stored frame is a contiguous
        on-disk span of the original tensor bytes, which is what lets the
        serving layer answer range requests with zero-copy ``os.sendfile``
        straight out of the container file. The decision is a pure function
        of (bytes, entropy backend), so the parallel/process engines stay
        bit-identical to the serial path."""
        if len(frame) < len(data):
            return "raw", frame
        return "stored", data


class BitXWriter:
    """Streams TensorRecords + frames into a .bitx container."""

    def __init__(self, level: int = DEFAULT_ZSTD_LEVEL, file_metadata: Optional[Dict] = None,
                 threads: int = 0):
        self.codec = BitXCodec(level=level, threads=threads)
        self.records: List[TensorRecord] = []
        self.frames: List[bytes] = []
        self.file_metadata = dict(file_metadata or {})

    def add_bitx(
        self, name: str, dtype_str: str, shape, base: np.ndarray, ft: np.ndarray,
        base_hash: str, self_hash: str,
    ) -> int:
        frames, raw = self.codec.encode_delta(base, ft)
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "bitx", base_hash, self_hash,
                         [len(f) for f in frames], raw)
        )
        self.frames.extend(frames)
        return sum(len(f) for f in frames)

    def add_zipnn(self, name: str, dtype_str: str, shape, x: np.ndarray, self_hash: str) -> int:
        frames, raw = self.codec.encode_planes(x)
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "zipnn", None, self_hash,
                         [len(f) for f in frames], raw)
        )
        self.frames.extend(frames)
        return sum(len(f) for f in frames)

    def add_raw(self, name: str, dtype_str: str, shape, data: bytes, self_hash: str) -> int:
        frame = self.codec.encode_raw(data)
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "raw", None, self_hash,
                         [len(frame)], len(data))
        )
        self.frames.append(frame)
        return len(frame)

    def add_dedup(self, name: str, dtype_str: str, shape, self_hash: str, raw_size: int) -> int:
        """Tensor already in the pool — store only the reference (0 payload)."""
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "dedup", None, self_hash, [], raw_size)
        )
        return 0

    def add_precomputed(self, name: str, dtype_str: str, shape, codec: str,
                        base_hash: Optional[str], self_hash: str,
                        frames: Sequence[bytes], raw_size: int) -> int:
        """Append a record whose frames were encoded elsewhere (the parallel
        ingest engine encodes off-thread, then merges in tensor order so the
        container bytes match the serial path exactly). Zero-payload dedup
        records go through :meth:`add_dedup` instead."""
        assert codec in ("bitx", "zipnn", "raw", "stored"), codec
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), codec, base_hash, self_hash,
                         [len(f) for f in frames], raw_size)
        )
        self.frames.extend(frames)
        return sum(len(f) for f in frames)

    def tobytes(self) -> bytes:
        header = {
            "metadata": self.file_metadata,
            "backend": zstd.BACKEND,
            "tensors": [r.to_json() for r in self.records],
        }
        hjson = json.dumps(header, separators=(",", ":")).encode()
        out = io.BytesIO()
        out.write(MAGIC)
        out.write(struct.pack("<Q", len(hjson)))
        out.write(hjson)
        for f in self.frames:
            out.write(f)
        return out.getvalue()

    def write(self, path: str, *, fault_hook=None, fsync: bool = False) -> int:
        """Write the container atomically: bytes land at ``path + TMP_SUFFIX``
        first and are renamed into place, so a crash at any instant leaves
        either no file, a ``.part`` temp (orphan-scan debris), or the
        complete container — never a torn file at the final path.

        ``fault_hook(point_name)`` is the crash-injection hook for the
        recovery test harness; it may raise to simulate a kill at that
        point. No cleanup runs when it does — the on-disk state is exactly
        what a real crash would leave (callers that *handle* failures, e.g.
        the ingest rollback, remove both ``path`` and the temp themselves).
        ``fsync=True`` flushes the temp file to stable storage before the
        rename (the compaction path, where the old copies are deleted soon
        after)."""
        blob = self.tobytes()
        if fault_hook is not None:
            fault_hook("writer.before_write")
        tmp = path + TMP_SUFFIX
        with open(tmp, "wb") as f:
            f.write(blob)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        if fault_hook is not None:
            fault_hook("writer.after_temp")
        os.replace(tmp, path)
        if fault_hook is not None:
            fault_hook("writer.after_rename")
        return len(blob)


class BitXReader:
    """Reads a .bitx container; decode requires a base-tensor resolver for
    bitx-coded records and a pool resolver for dedup'd records.

    ``open(path)`` memory-maps the container: only the header is parsed
    eagerly, frames are lazy zero-copy slices of the map
    (:meth:`frames_for` returns memoryviews), so resolving a single tensor
    out of a multi-GB container touches just that tensor's pages. A reader
    is safe to share across decode worker threads (the codec keeps its
    zstd contexts thread-local); call :meth:`close` to drop the map.
    """

    def __init__(self, data):
        view = memoryview(data)
        assert bytes(view[:8]) == MAGIC, "not a BitX container"
        (hlen,) = struct.unpack("<Q", view[8:16])
        header = json.loads(bytes(view[16 : 16 + hlen]))
        backend = header.get("backend", zstd.BACKEND)
        if backend != zstd.BACKEND:
            raise ValueError(
                f"container written with entropy backend {backend!r} but this "
                f"process runs {zstd.BACKEND!r} (see repro.core.zstd_compat)")
        self.file_metadata: Dict = header.get("metadata", {})
        self.records = [TensorRecord.from_json(r) for r in header["tensors"]]
        self._name_to_idx: Optional[Dict[str, int]] = None
        self._payload = view[16 + hlen :]
        # absolute file offset where the frame payload begins — frame spans
        # (``frame_span``) are payload-relative and need this to become
        # sendfile-able (path, offset, length) triples
        self.payload_offset = 16 + hlen
        self.path: Optional[str] = None  # set by open(); None for byte-backed
        self._mmap: Optional[mmap.mmap] = None
        self._file = None
        # frame offsets in record order
        self._offsets: List[List[Tuple[int, int]]] = []
        off = 0
        for r in self.records:
            sizes = r.plane_sizes
            spans = []
            for s in sizes:
                spans.append((off, off + s))
                off += s
            self._offsets.append(spans)
        self.codec = BitXCodec()

    @staticmethod
    def open(path: str, use_mmap: bool = True) -> "BitXReader":
        if not use_mmap:
            with open(path, "rb") as f:
                return BitXReader(f.read())
        f = open(path, "rb")
        mm = None
        try:
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
            reader = BitXReader(mm)  # may raise (bad magic, backend mismatch)
        except Exception:
            if mm is not None:
                try:
                    mm.close()
                except BufferError:
                    # the raising frame still exports a view over the map;
                    # GC finalizes it once the traceback is released
                    pass
            f.close()  # the fd is the scarce resource — always release it
            raise
        reader._mmap, reader._file = mm, f
        reader.path = path
        return reader

    def close(self) -> None:
        """Release the memory map (no-op for byte-backed readers). Frames
        already handed out keep the map alive until they are collected."""
        self._payload = memoryview(b"")
        if self._mmap is not None:
            try:
                self._mmap.close()
            except BufferError:
                pass  # exported frame views still alive; GC finishes the job
            self._mmap = None
        if self._file is not None:
            self._file.close()
            self._file = None

    @property
    def payload_size(self) -> int:
        """Actual payload bytes behind the header (mmap/bytes length)."""
        return len(self._payload)

    @property
    def expected_payload_size(self) -> int:
        """Payload bytes the header's plane_sizes promise. A container whose
        actual payload is shorter was truncated — fsck flags it corrupt."""
        return sum(s for r in self.records for s in r.plane_sizes)

    def index_of(self, name: str) -> int:
        """Record index for a tensor name (KeyError if absent). The map is
        built lazily once per reader — tensor-granular serving resolves by
        name on every request, so the lookup must not rescan the records.
        Safe under concurrent builders: both compute the same dict and the
        attribute store is atomic."""
        m = self._name_to_idx
        if m is None:
            m = self._name_to_idx = {r.name: i for i, r in enumerate(self.records)}
        return m[name]

    def frames_for(self, idx: int) -> List[memoryview]:
        return [self._payload[b:e] for b, e in self._offsets[idx]]

    def frame_span(self, idx: int) -> Tuple[int, int]:
        """(absolute file offset, length) of record ``idx``'s contiguous
        frame bytes. For ``stored`` records this span IS the tensor's raw
        little-endian bytes on disk — the serving layer's zero-copy
        ``os.sendfile`` source."""
        spans = self._offsets[idx]
        if not spans:
            return self.payload_offset, 0
        return self.payload_offset + spans[0][0], spans[-1][1] - spans[0][0]

    def decode_tensor(self, idx: int, base_resolver, pool_resolver) -> np.ndarray:
        """Decode record ``idx`` to its raw bit-view array.

        ``base_resolver(base_hash) -> np.ndarray`` and
        ``pool_resolver(self_hash) -> np.ndarray`` fetch dependencies (CAS pool).
        """
        from repro.formats.safetensors import STR_TO_DTYPE

        r = self.records[idx]
        np_dtype = STR_TO_DTYPE[r.dtype_str]
        if r.codec == "dedup":
            arr = pool_resolver(r.self_hash)
            return np.frombuffer(arr, np_dtype).reshape(r.shape) if isinstance(arr, (bytes, memoryview)) else arr.reshape(r.shape)
        frames = self.frames_for(idx)
        if r.codec == "bitx":
            base = base_resolver(r.base_hash)
            if isinstance(base, (bytes, memoryview)):
                base = np.frombuffer(base, np_dtype)
            return self.codec.decode_delta(frames, base.reshape(-1)).reshape(r.shape)
        if r.codec == "zipnn":
            return self.codec.decode_planes(frames, np_dtype, r.shape)
        if r.codec == "raw":
            return np.frombuffer(self.codec.decode_raw(frames[0]), np_dtype).reshape(r.shape)
        if r.codec == "stored":
            # verbatim frame: the on-disk bytes ARE the tensor bytes
            return np.frombuffer(frames[0], np_dtype).reshape(r.shape)
        raise ValueError(f"unknown codec {r.codec}")
