"""BitX lossless delta compression (paper §4.3).

Encode: align the floats of a fine-tuned tensor with its base tensor in
serialization order, bitcast both to unsigned words, XOR, split the delta into
byte planes (MSB plane ≈ all zeros within a family, Fig. 5), entropy-code each
plane with zstd. Decode is the exact inverse; the pipeline verifies bit-exact
reconstruction.

Array math goes through an :class:`ArrayBackend` selected once per store
(``get_backend("numpy"|"jax"|"auto")``), two implementations tested
bit-identical:

* ``numpy`` — host path for mmap'd safetensors ingestion (the
  evaluation/throughput path, mirroring the paper's C++ engine);
* ``jax`` — the Pallas kernels (``repro.kernels``), the TPU deployment path:
  same-width tensors are concatenated per bucket and transformed in ONE
  fused kernel launch (interpret mode off-TPU, so tests validate the kernel
  bodies on CPU). ``auto`` picks jax only when an accelerator is attached.

The per-codec encode/decode lanes live in the :mod:`repro.core.codecs`
registry; :class:`BitXCodec` remains as a thin back-compat facade over it.

Container format (``.bitx``): a 16-byte magic+version, a JSON header
describing per-tensor records, then concatenated zstd frames. Per-tensor
records keep the base tensor's content hash so retrieval can fetch the base
from the CAS pool (§4.4.4).
"""

from __future__ import annotations

import io
import json
import mmap
import os
import struct
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core import zstd_compat as zstd
from repro.core.codecs import CodecRuntime, EncodeInput, get_codec, raw_or_stored

__all__ = [
    "ArrayBackend",
    "BitXCodec",
    "TensorRecord",
    "BitXWriter",
    "BitXReader",
    "JaxBackend",
    "NumpyBackend",
    "TMP_SUFFIX",
    "get_backend",
    "xor_delta_planes_np",
    "merge_planes_xor_np",
    "byte_planes_np",
]

MAGIC = b"BITX0001"
DEFAULT_ZSTD_LEVEL = 3

# Containers are written to ``<path>.part`` and atomically renamed into
# place, so a crash mid-write can never leave a torn file at a path the
# index might reference. Leftover ``.part`` files are crash debris; the
# store's fsck orphan scan recognizes the suffix and deletes them under
# repair (they are never referenced by the version graph).
TMP_SUFFIX = ".part"


def _bit_view_np(arr: np.ndarray) -> np.ndarray:
    """View a numpy array as unsigned words of the same width (no copy)."""
    if arr.dtype.kind == "u":
        return arr
    if arr.dtype.kind in ("f", "i"):
        return arr.view(f"<u{arr.dtype.itemsize}")
    raise ValueError(f"unsupported dtype {arr.dtype}")


# ---------------------------------------------------------------------------
# Host (numpy) transform implementations — the reference semantics every
# ArrayBackend must match bit for bit.
# ---------------------------------------------------------------------------

def _xor_delta_planes_host(base: np.ndarray, ft: np.ndarray) -> List[np.ndarray]:
    """XOR bit views and split into byte planes (MSB first). The plane split
    is a strided view of the little-endian byte buffer, so the whole encode
    is two passes over memory (XOR, then per-plane copy)."""
    a = _bit_view_np(np.ascontiguousarray(base)).reshape(-1)
    b = _bit_view_np(np.ascontiguousarray(ft)).reshape(-1)
    assert a.shape == b.shape and a.dtype == b.dtype, (a.shape, b.shape, a.dtype, b.dtype)
    delta = np.bitwise_xor(a, b)
    nb = delta.dtype.itemsize
    raw = delta.view(np.uint8).reshape(-1, nb)
    # little-endian: byte column nb-1 is the MSB
    return [np.ascontiguousarray(raw[:, nb - 1 - i]) for i in range(nb)]


def _byte_planes_host(x: np.ndarray) -> List[np.ndarray]:
    """MSB-first byte planes of ``x``'s bit view (the ZipNN split)."""
    v = _bit_view_np(np.ascontiguousarray(x)).reshape(-1)
    nb = v.dtype.itemsize
    raw = v.view(np.uint8).reshape(-1, nb)
    return [np.ascontiguousarray(raw[:, nb - 1 - i]) for i in range(nb)]


def _merge_planes_xor_host(planes: Sequence[np.ndarray], base: np.ndarray) -> np.ndarray:
    """Inverse of :func:`_xor_delta_planes_host`; returns the ft bit view
    shaped like ``base``."""
    a = _bit_view_np(np.ascontiguousarray(base))
    nb = a.dtype.itemsize
    assert len(planes) == nb
    n = a.size
    raw = np.empty((n, nb), np.uint8)
    for i, p in enumerate(planes):
        raw[:, nb - 1 - i] = p
    delta = raw.reshape(-1).view(a.dtype.str)
    return np.bitwise_xor(delta, a.reshape(-1)).reshape(a.shape)


def _merge_planes_host(planes: Sequence[np.ndarray], dtype_np, shape) -> np.ndarray:
    """Inverse of :func:`_byte_planes_host`; returns an array of ``dtype_np``
    (the ZipNN merge)."""
    nb = np.dtype(dtype_np).itemsize
    assert len(planes) == nb
    n = int(np.prod(shape)) if len(shape) else 1
    raw = np.empty((n, nb), np.uint8)
    for i, p in enumerate(planes):
        raw[:, nb - 1 - i] = p
    return raw.reshape(-1).view(np.dtype(dtype_np).str).reshape(shape)


# ---------------------------------------------------------------------------
# ArrayBackend: the one dispatch point for the pipeline's array math.
# ---------------------------------------------------------------------------

class ArrayBackend(Protocol):
    """Array-transform provider selected once at ``ZLLMStore`` construction.

    Single-tensor ops are the reference semantics; the ``*_batch`` variants
    take many tensors at once and MUST produce per-tensor results identical
    to mapping the single op — backends exploit that freedom to concatenate
    same-width tensors and run one fused kernel launch per bucket. The
    transforms are elementwise in the bit view, so batching can never change
    the emitted bytes.
    """

    name: str
    supports_batching: bool

    def xor_delta_planes(self, base: np.ndarray, ft: np.ndarray) -> List[np.ndarray]: ...
    def byte_planes(self, x: np.ndarray) -> List[np.ndarray]: ...
    def merge_planes_xor(self, planes: Sequence[np.ndarray], base: np.ndarray) -> np.ndarray: ...
    def merge_planes(self, planes: Sequence[np.ndarray], dtype_np, shape) -> np.ndarray: ...
    def xor_delta_planes_batch(self, pairs: Sequence[Tuple[np.ndarray, np.ndarray]]) -> List[List[np.ndarray]]: ...
    def byte_planes_batch(self, xs: Sequence[np.ndarray]) -> List[List[np.ndarray]]: ...
    def merge_planes_xor_batch(self, items: Sequence[Tuple[Sequence[np.ndarray], np.ndarray]]) -> List[np.ndarray]: ...
    def merge_planes_batch(self, items: Sequence[Tuple[Sequence[np.ndarray], np.dtype, Tuple[int, ...]]]) -> List[np.ndarray]: ...


class NumpyBackend:
    """Host path: strided-view plane splits on the ingest thread(s). Batched
    entry points degenerate to a loop — numpy gains nothing from fusion, and
    the pipeline only engages its batching stage for backends that declare
    ``supports_batching``."""

    name = "numpy"
    supports_batching = False

    def xor_delta_planes(self, base, ft):
        return _xor_delta_planes_host(base, ft)

    def byte_planes(self, x):
        return _byte_planes_host(x)

    def merge_planes_xor(self, planes, base):
        return _merge_planes_xor_host(planes, base)

    def merge_planes(self, planes, dtype_np, shape):
        return _merge_planes_host(planes, dtype_np, shape)

    def xor_delta_planes_batch(self, pairs):
        return [_xor_delta_planes_host(b, f) for b, f in pairs]

    def byte_planes_batch(self, xs):
        return [_byte_planes_host(x) for x in xs]

    def merge_planes_xor_batch(self, items):
        return [_merge_planes_xor_host(p, b) for p, b in items]

    def merge_planes_batch(self, items):
        return [_merge_planes_host(p, d, s) for p, d, s in items]


class JaxBackend:
    """Device path over the Pallas kernels (``repro.kernels.ops``).

    Inputs are converted to their unsigned bit views host-side (so int8 and
    bool-free integer tensors work without kernel-side dtype plumbing), then
    the fused XOR+split / merge kernels run once per same-width bucket: a
    batch of N same-dtype tensors is concatenated flat and transformed in a
    single launch, and per-tensor planes are sliced back out — bit-identical
    to the per-tensor host path because the transforms are elementwise.

    Off-TPU the kernels execute in interpret mode (`ops._interpret`), which
    is how the equivalence tests validate the kernel bodies on CPU. 8-byte
    words fall back to the host implementation unless jax runs with x64
    enabled (jax would silently truncate uint64 otherwise).
    """

    name = "jax"
    supports_batching = True

    def __init__(self, use_pallas: bool = True):
        self.use_pallas = use_pallas
        self._ops_mod = None

    @staticmethod
    def available() -> bool:
        import importlib.util
        return importlib.util.find_spec("jax") is not None

    def _ops(self):
        if self._ops_mod is None:
            try:
                from repro.kernels import ops as ops_mod
            except Exception as e:  # missing/broken jax toolchain
                raise RuntimeError(
                    "backend='jax' needs the jax/Pallas toolchain "
                    "(repro.kernels.ops failed to import); construct the "
                    "store with backend='numpy' or 'auto'") from e
            self._ops_mod = ops_mod
        return self._ops_mod

    def _device_ok(self, dtype: np.dtype) -> bool:
        """uint64 needs jax x64; without it jnp.asarray silently truncates."""
        if np.dtype(dtype).itemsize < 8:
            return True
        import jax
        return bool(jax.config.jax_enable_x64)

    # -- single-tensor ops (reference semantics) -----------------------------
    def xor_delta_planes(self, base, ft):
        return self.xor_delta_planes_batch([(base, ft)])[0]

    def byte_planes(self, x):
        return self.byte_planes_batch([x])[0]

    def merge_planes_xor(self, planes, base):
        return self.merge_planes_xor_batch([(planes, base)])[0]

    def merge_planes(self, planes, dtype_np, shape):
        return self.merge_planes_batch([(planes, dtype_np, shape)])[0]

    # -- batched ops: one kernel launch per same-width bucket ----------------
    def _buckets(self, dtypes: Sequence[np.dtype]) -> Dict[str, List[int]]:
        groups: Dict[str, List[int]] = {}
        for i, d in enumerate(dtypes):
            groups.setdefault(np.dtype(d).str, []).append(i)
        return groups

    def xor_delta_planes_batch(self, pairs):
        out: List[Optional[List[np.ndarray]]] = [None] * len(pairs)
        views = []
        for base, ft in pairs:
            a = _bit_view_np(np.ascontiguousarray(base)).reshape(-1)
            b = _bit_view_np(np.ascontiguousarray(ft)).reshape(-1)
            assert a.shape == b.shape and a.dtype == b.dtype, \
                (a.shape, b.shape, a.dtype, b.dtype)
            views.append((a, b))
        for dstr, idxs in self._buckets([v[0].dtype for v in views]).items():
            if not self._device_ok(np.dtype(dstr)):
                for i in idxs:
                    out[i] = _xor_delta_planes_host(*views[i])
                continue
            cat_a = np.concatenate([views[i][0] for i in idxs])
            cat_b = np.concatenate([views[i][1] for i in idxs])
            planes = [np.asarray(p) for p in self._ops().bitx_encode_planes(
                cat_a, cat_b, use_pallas=self.use_pallas)]
            off = 0
            for i in idxs:
                n = views[i][0].size
                out[i] = [np.ascontiguousarray(p[off:off + n]) for p in planes]
                off += n
        return out

    def byte_planes_batch(self, xs):
        out: List[Optional[List[np.ndarray]]] = [None] * len(xs)
        views = [_bit_view_np(np.ascontiguousarray(x)).reshape(-1) for x in xs]
        for dstr, idxs in self._buckets([v.dtype for v in views]).items():
            if not self._device_ok(np.dtype(dstr)):
                for i in idxs:
                    out[i] = _byte_planes_host(views[i])
                continue
            cat = np.concatenate([views[i] for i in idxs])
            planes = [np.asarray(p) for p in self._ops().zipnn_split_planes(
                cat, use_pallas=self.use_pallas)]
            off = 0
            for i in idxs:
                n = views[i].size
                out[i] = [np.ascontiguousarray(p[off:off + n]) for p in planes]
                off += n
        return out

    def merge_planes_xor_batch(self, items):
        out: List[Optional[np.ndarray]] = [None] * len(items)
        views = [_bit_view_np(np.ascontiguousarray(base)) for _, base in items]
        for dstr, idxs in self._buckets([v.dtype for v in views]).items():
            if not self._device_ok(np.dtype(dstr)):
                for i in idxs:
                    out[i] = _merge_planes_xor_host(items[i][0], views[i])
                continue
            nb = np.dtype(dstr).itemsize
            cat_base = np.concatenate([views[i].reshape(-1) for i in idxs])
            cat_planes = [
                np.concatenate([np.ascontiguousarray(np.asarray(items[i][0][pi]))
                                for i in idxs])
                for pi in range(nb)]
            merged = np.asarray(self._ops().bitx_decode_planes(
                cat_planes, cat_base, use_pallas=self.use_pallas))
            off = 0
            for i in idxs:
                n = views[i].size
                out[i] = np.ascontiguousarray(
                    merged[off:off + n]).reshape(views[i].shape)
                off += n
        return out

    def merge_planes_batch(self, items):
        out: List[Optional[np.ndarray]] = [None] * len(items)
        dtypes = [np.dtype(d) for _, d, _ in items]
        for dstr, idxs in self._buckets(dtypes).items():
            dtype_np = np.dtype(dstr)
            nb = dtype_np.itemsize
            if not self._device_ok(dtype_np):
                for i in idxs:
                    out[i] = _merge_planes_host(*items[i])
                continue
            uview = np.dtype(f"<u{nb}")
            cat_planes = [
                np.concatenate([np.ascontiguousarray(np.asarray(items[i][0][pi]))
                                for i in idxs])
                for pi in range(nb)]
            total = int(cat_planes[0].size)
            merged = np.asarray(self._ops().zipnn_merge_planes(
                cat_planes, uview, (total,), use_pallas=self.use_pallas))
            off = 0
            for i in idxs:
                shape = items[i][2]
                n = int(np.prod(shape)) if len(shape) else 1
                out[i] = np.ascontiguousarray(
                    merged[off:off + n]).view(dtype_np.str).reshape(shape)
                off += n
        return out


_BACKENDS: Dict[str, ArrayBackend] = {}


def get_backend(spec="auto") -> ArrayBackend:
    """Resolve an array backend: ``"numpy"``, ``"jax"``, ``"auto"``, or an
    :class:`ArrayBackend` instance (passed through).

    ``"auto"`` picks jax only when an accelerator is actually attached
    (``jax.default_backend() != "cpu"``) — on CPU-only boxes the numpy host
    path wins by a wide margin (interpret-mode kernels are Python emulation),
    so auto-fallback keeps ingest throughput unregressed.
    """
    if not isinstance(spec, str):
        return spec
    cached = _BACKENDS.get(spec)
    if cached is not None:
        return cached
    if spec == "numpy":
        backend: ArrayBackend = NumpyBackend()
    elif spec == "jax":
        backend = JaxBackend()
    elif spec == "auto":
        backend = NumpyBackend()
        if JaxBackend.available():
            try:
                import jax
                if jax.default_backend() != "cpu":
                    backend = JaxBackend()
            except Exception:
                pass  # broken jax install: the host path always works
    else:
        raise ValueError(f"unknown array backend {spec!r} "
                         f"(expected 'numpy', 'jax' or 'auto')")
    _BACKENDS[spec] = backend
    return backend


# ---------------------------------------------------------------------------
# Deprecated free-function aliases (one-release shim): external callers used
# to import the host transforms directly; array math now routes through an
# ArrayBackend so the jax device path is substitutable.
# ---------------------------------------------------------------------------

def _warn_shim(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.bitx.{old} is deprecated; use "
        f"repro.core.bitx.get_backend(...).{new} instead "
        f"(this shim will be removed next release)",
        DeprecationWarning, stacklevel=3)


def xor_delta_planes_np(base: np.ndarray, ft: np.ndarray) -> List[np.ndarray]:
    """Deprecated alias of ``get_backend("numpy").xor_delta_planes``."""
    _warn_shim("xor_delta_planes_np", "xor_delta_planes")
    return _xor_delta_planes_host(base, ft)


def byte_planes_np(x: np.ndarray) -> List[np.ndarray]:
    """Deprecated alias of ``get_backend("numpy").byte_planes``."""
    _warn_shim("byte_planes_np", "byte_planes")
    return _byte_planes_host(x)


def merge_planes_xor_np(planes: Sequence[np.ndarray], base: np.ndarray) -> np.ndarray:
    """Deprecated alias of ``get_backend("numpy").merge_planes_xor``."""
    _warn_shim("merge_planes_xor_np", "merge_planes_xor")
    return _merge_planes_xor_host(planes, base)


@dataclass
class TensorRecord:
    """Header record for one tensor inside a .bitx container."""

    name: str
    dtype_str: str            # safetensors tag of the original tensor ("BF16", "F32", ...)
    shape: Tuple[int, ...]
    codec: str                # "bitx" | "bitxq" | "zipnn" | "raw" | "stored" | "dedup"
    base_hash: Optional[str]  # CAS hash of the base tensor (bitx/bitxq) / None
    self_hash: str            # CAS hash of this tensor's raw bytes (dedup + verify)
    plane_sizes: List[int] = field(default_factory=list)  # compressed bytes per plane
    raw_size: int = 0
    # quantized-delta (bitxq) stamp — emitted only when set, so containers
    # that never use the lane stay byte-identical to pre-bitxq builds.
    # ``qscale_bits`` is the float32 scale's raw bit pattern (uint32): round-
    # tripping the scale through JSON as a decimal float could perturb the
    # last bit and break the decode-side prediction replay.
    base_dtype: Optional[str] = None   # safetensors tag of the base ("BF16", ...)
    qscale_bits: Optional[int] = None  # float32 bit pattern of the quant scale
    qzero_point: Optional[int] = None  # integer zero point of the quant grid

    def to_json(self) -> Dict:
        d = {
            "name": self.name,
            "dtype": self.dtype_str,
            "shape": list(self.shape),
            "codec": self.codec,
            "base_hash": self.base_hash,
            "self_hash": self.self_hash,
            "plane_sizes": self.plane_sizes,
            "raw_size": self.raw_size,
        }
        if self.base_dtype is not None:
            d["base_dtype"] = self.base_dtype
        if self.qscale_bits is not None:
            d["qscale_bits"] = self.qscale_bits
        if self.qzero_point is not None:
            d["qzero_point"] = self.qzero_point
        return d

    @staticmethod
    def from_json(d: Dict) -> "TensorRecord":
        qs = d.get("qscale_bits")
        qz = d.get("qzero_point")
        return TensorRecord(
            name=d["name"],
            dtype_str=d["dtype"],
            shape=tuple(d["shape"]),
            codec=d["codec"],
            base_hash=d.get("base_hash"),
            self_hash=d["self_hash"],
            plane_sizes=list(d.get("plane_sizes", [])),
            raw_size=int(d.get("raw_size", 0)),
            base_dtype=d.get("base_dtype"),
            qscale_bits=int(qs) if qs is not None else None,
            qzero_point=int(qz) if qz is not None else None,
        )


class BitXCodec:
    """Back-compat facade over the codec registry (kept for one release).

    New code goes through :mod:`repro.core.codecs` directly; this class maps
    the old per-codec ``encode_*``/``decode_*`` methods onto registry lanes
    sharing one :class:`~repro.core.codecs.CodecRuntime`. The runtime owns
    the zstd contexts per worker thread (compressor objects are not
    thread-safe), so a codec instance is still safe to share across a pool.
    ``threads`` is forwarded to ``zstd.ZstdCompressor(threads=...)``.
    """

    def __init__(self, level: int = DEFAULT_ZSTD_LEVEL, threads: int = 0,
                 backend=None):
        self.level = level
        self.threads = threads
        self.runtime = CodecRuntime(level=level, threads=threads,
                                    backend=get_backend(backend or "numpy"))

    @property
    def _cctx(self):
        return self.runtime._compressor()

    @property
    def _dctx(self):
        return self.runtime._decompressor()

    # -- BitX ---------------------------------------------------------------
    def encode_delta(self, base: np.ndarray, ft: np.ndarray) -> Tuple[List[bytes], int]:
        """Returns (compressed plane frames MSB-first, raw byte size)."""
        _, frames, raw = get_codec("bitx").encode(
            self.runtime, EncodeInput(data=ft, base=base))
        return frames, raw

    def decode_delta(
        self, frames: Sequence[bytes], base: np.ndarray
    ) -> np.ndarray:
        planes = [np.frombuffer(self.runtime.decompress(f), np.uint8) for f in frames]
        return self.runtime.backend.merge_planes_xor(planes, base)

    # -- ZipNN fallback (no base available, §4.4.3) ---------------------------
    def encode_planes(self, x: np.ndarray) -> Tuple[List[bytes], int]:
        _, frames, raw = get_codec("zipnn").encode(self.runtime, EncodeInput(data=x))
        return frames, raw

    def decode_planes(self, frames: Sequence[bytes], dtype_np: np.dtype, shape) -> np.ndarray:
        planes = [np.frombuffer(self.runtime.decompress(f), np.uint8) for f in frames]
        return self.runtime.backend.merge_planes(planes, dtype_np, shape)

    # -- raw zstd (non-float / last resort) ----------------------------------
    def encode_raw(self, data: bytes) -> bytes:
        return self.runtime.compress(data)

    def decode_raw(self, frame: bytes) -> bytes:
        return self.runtime.decompress(frame)

    # -- stored (verbatim) ----------------------------------------------------
    @staticmethod
    def choose_raw_codec(data: bytes, frame: bytes) -> Tuple[str, bytes]:
        """Deprecated alias of :func:`repro.core.codecs.raw_or_stored`."""
        return raw_or_stored(data, frame)


class BitXWriter:
    """Streams TensorRecords + frames into a .bitx container."""

    def __init__(self, level: int = DEFAULT_ZSTD_LEVEL, file_metadata: Optional[Dict] = None,
                 threads: int = 0, backend=None):
        self.codec = BitXCodec(level=level, threads=threads, backend=backend)
        self.records: List[TensorRecord] = []
        self.frames: List[bytes] = []
        self.file_metadata = dict(file_metadata or {})

    def add_bitx(
        self, name: str, dtype_str: str, shape, base: np.ndarray, ft: np.ndarray,
        base_hash: str, self_hash: str,
    ) -> int:
        frames, raw = self.codec.encode_delta(base, ft)
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "bitx", base_hash, self_hash,
                         [len(f) for f in frames], raw)
        )
        self.frames.extend(frames)
        return sum(len(f) for f in frames)

    def add_zipnn(self, name: str, dtype_str: str, shape, x: np.ndarray, self_hash: str) -> int:
        frames, raw = self.codec.encode_planes(x)
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "zipnn", None, self_hash,
                         [len(f) for f in frames], raw)
        )
        self.frames.extend(frames)
        return sum(len(f) for f in frames)

    def add_raw(self, name: str, dtype_str: str, shape, data: bytes, self_hash: str) -> int:
        frame = self.codec.encode_raw(data)
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "raw", None, self_hash,
                         [len(frame)], len(data))
        )
        self.frames.append(frame)
        return len(frame)

    def add_dedup(self, name: str, dtype_str: str, shape, self_hash: str, raw_size: int) -> int:
        """Tensor already in the pool — store only the reference (0 payload)."""
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), "dedup", None, self_hash, [], raw_size)
        )
        return 0

    def add_precomputed(self, name: str, dtype_str: str, shape, codec: str,
                        base_hash: Optional[str], self_hash: str,
                        frames: Sequence[bytes], raw_size: int,
                        extras: Optional[Dict] = None) -> int:
        """Append a record whose frames were encoded elsewhere (the parallel
        ingest engine encodes off-thread, then merges in tensor order so the
        container bytes match the serial path exactly). ``extras`` carries
        optional stamp fields a lane needs replayed at decode time (the
        quantized-delta lane's ``base_dtype``/``qscale_bits``/``qzero_point``).
        Zero-payload dedup records go through :meth:`add_dedup` instead."""
        assert codec in ("bitx", "bitxq", "zipnn", "raw", "stored"), codec
        self.records.append(
            TensorRecord(name, dtype_str, tuple(shape), codec, base_hash, self_hash,
                         [len(f) for f in frames], raw_size, **(extras or {}))
        )
        self.frames.extend(frames)
        return sum(len(f) for f in frames)

    def tobytes(self) -> bytes:
        header = {
            "metadata": self.file_metadata,
            "backend": zstd.BACKEND,
            "tensors": [r.to_json() for r in self.records],
        }
        hjson = json.dumps(header, separators=(",", ":")).encode()
        out = io.BytesIO()
        out.write(MAGIC)
        out.write(struct.pack("<Q", len(hjson)))
        out.write(hjson)
        for f in self.frames:
            out.write(f)
        return out.getvalue()

    def write(self, path: str, *, fault_hook=None, fsync: bool = False) -> int:
        """Write the container atomically: bytes land at ``path + TMP_SUFFIX``
        first and are renamed into place, so a crash at any instant leaves
        either no file, a ``.part`` temp (orphan-scan debris), or the
        complete container — never a torn file at the final path.

        ``fault_hook(point_name)`` is the crash-injection hook for the
        recovery test harness; it may raise to simulate a kill at that
        point. No cleanup runs when it does — the on-disk state is exactly
        what a real crash would leave (callers that *handle* failures, e.g.
        the ingest rollback, remove both ``path`` and the temp themselves).
        ``fsync=True`` flushes the temp file to stable storage before the
        rename (the compaction path, where the old copies are deleted soon
        after)."""
        blob = self.tobytes()
        if fault_hook is not None:
            fault_hook("writer.before_write")
        tmp = path + TMP_SUFFIX
        with open(tmp, "wb") as f:
            f.write(blob)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        if fault_hook is not None:
            fault_hook("writer.after_temp")
        os.replace(tmp, path)
        if fault_hook is not None:
            fault_hook("writer.after_rename")
        return len(blob)


class BitXReader:
    """Reads a .bitx container; decode requires a base-tensor resolver for
    bitx-coded records and a pool resolver for dedup'd records.

    ``open(path)`` memory-maps the container: only the header is parsed
    eagerly, frames are lazy zero-copy slices of the map
    (:meth:`frames_for` returns memoryviews), so resolving a single tensor
    out of a multi-GB container touches just that tensor's pages. A reader
    is safe to share across decode worker threads (the runtime keeps its
    zstd contexts thread-local); call :meth:`close` to drop the map.

    ``runtime`` selects the entropy settings and array backend used for
    decode (the store passes its own); the default is a numpy-backed
    runtime at default settings — decode output is identical either way.
    """

    def __init__(self, data, runtime: Optional[CodecRuntime] = None):
        view = memoryview(data)
        assert bytes(view[:8]) == MAGIC, "not a BitX container"
        (hlen,) = struct.unpack("<Q", view[8:16])
        header = json.loads(bytes(view[16 : 16 + hlen]))
        backend = header.get("backend", zstd.BACKEND)
        if backend != zstd.BACKEND:
            raise ValueError(
                f"container written with entropy backend {backend!r} but this "
                f"process runs {zstd.BACKEND!r} (see repro.core.zstd_compat)")
        self.file_metadata: Dict = header.get("metadata", {})
        self.records = [TensorRecord.from_json(r) for r in header["tensors"]]
        self._name_to_idx: Optional[Dict[str, int]] = None
        self._payload = view[16 + hlen :]
        # absolute file offset where the frame payload begins — frame spans
        # (``frame_span``) are payload-relative and need this to become
        # sendfile-able (path, offset, length) triples
        self.payload_offset = 16 + hlen
        self.path: Optional[str] = None  # set by open(); None for byte-backed
        self._mmap: Optional[mmap.mmap] = None
        self._file = None
        # frame offsets in record order
        self._offsets: List[List[Tuple[int, int]]] = []
        off = 0
        for r in self.records:
            sizes = r.plane_sizes
            spans = []
            for s in sizes:
                spans.append((off, off + s))
                off += s
            self._offsets.append(spans)
        self.runtime = runtime if runtime is not None else CodecRuntime()

    @staticmethod
    def open(path: str, use_mmap: bool = True,
             runtime: Optional[CodecRuntime] = None) -> "BitXReader":
        if not use_mmap:
            with open(path, "rb") as f:
                return BitXReader(f.read(), runtime=runtime)
        f = open(path, "rb")
        mm = None
        try:
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
            reader = BitXReader(mm, runtime=runtime)  # may raise (bad magic, backend mismatch)
        except Exception:
            if mm is not None:
                try:
                    mm.close()
                except BufferError:
                    # the raising frame still exports a view over the map;
                    # GC finalizes it once the traceback is released
                    pass
            f.close()  # the fd is the scarce resource — always release it
            raise
        reader._mmap, reader._file = mm, f
        reader.path = path
        return reader

    def close(self) -> None:
        """Release the memory map (no-op for byte-backed readers). Frames
        already handed out keep the map alive until they are collected."""
        self._payload = memoryview(b"")
        if self._mmap is not None:
            try:
                self._mmap.close()
            except BufferError:
                pass  # exported frame views still alive; GC finishes the job
            self._mmap = None
        if self._file is not None:
            self._file.close()
            self._file = None

    @property
    def payload_size(self) -> int:
        """Actual payload bytes behind the header (mmap/bytes length)."""
        return len(self._payload)

    @property
    def expected_payload_size(self) -> int:
        """Payload bytes the header's plane_sizes promise. A container whose
        actual payload is shorter was truncated — fsck flags it corrupt."""
        return sum(s for r in self.records for s in r.plane_sizes)

    def index_of(self, name: str) -> int:
        """Record index for a tensor name (KeyError if absent). The map is
        built lazily once per reader — tensor-granular serving resolves by
        name on every request, so the lookup must not rescan the records.
        Safe under concurrent builders: both compute the same dict and the
        attribute store is atomic."""
        m = self._name_to_idx
        if m is None:
            m = self._name_to_idx = {r.name: i for i, r in enumerate(self.records)}
        return m[name]

    def frames_for(self, idx: int) -> List[memoryview]:
        return [self._payload[b:e] for b, e in self._offsets[idx]]

    def frame_span(self, idx: int) -> Tuple[int, int]:
        """(absolute file offset, length) of record ``idx``'s contiguous
        frame bytes. For ``stored`` records this span IS the tensor's raw
        little-endian bytes on disk — the serving layer's zero-copy
        ``os.sendfile`` source."""
        spans = self._offsets[idx]
        if not spans:
            return self.payload_offset, 0
        return self.payload_offset + spans[0][0], spans[-1][1] - spans[0][0]

    def decode_tensor(self, idx: int, base_resolver, pool_resolver) -> np.ndarray:
        """Decode record ``idx`` to its raw bit-view array via the codec
        registry (an unknown stamped codec raises ``ValueError`` naming it).

        ``base_resolver(base_hash) -> np.ndarray`` and
        ``pool_resolver(self_hash) -> np.ndarray`` fetch dependencies (CAS pool).
        """
        from repro.formats.safetensors import STR_TO_DTYPE

        r = self.records[idx]
        codec = get_codec(r.codec)
        return codec.decode(self.runtime, r, self.frames_for(idx),
                            STR_TO_DTYPE[r.dtype_str], base_resolver, pool_resolver)
