"""FastCDC content-defined chunking — the ChunkDedup baseline (paper §2.1, §5.3.1).

Gear-hash rolling fingerprint with normalized chunking (FastCDC'16): two
masks (stricter before the normal point, looser after) centre the chunk-size
distribution; min/max clamps bound metadata. Defaults give ~64 KiB average
chunks (the paper's Table 5 corpus averages 0.085 MB).

Implementation note: the gear recurrence fp_i = (fp_{i-1} << 1) + G[b_i] over
uint64 is EXACTLY a 64-tap windowed sum fp_i = Σ_{j<64} G[b_{i-j}] << j
(shifts ≥ 64 overflow out), so we compute the fingerprint for the whole
buffer with 64 vectorized shifted adds and then walk cut points with
searchsorted — orders of magnitude faster than a per-byte Python loop. Unlike
textbook FastCDC the fingerprint window does not reset at chunk boundaries
(a windowed-gear variant); boundaries remain purely content-defined, which is
the property the dedup comparison needs.

This baseline is deliberately LLM-oblivious — it sees a byte stream — which
is exactly the property the paper critiques: chunk boundaries cut across
float/tensor boundaries, so post-dedup unique chunks are misaligned for
model-aware compressors.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.dedup import DedupStats

__all__ = ["FastCDC", "ChunkDedup", "GEAR", "gear_fingerprints"]

# 256-entry gear table, fixed seed for reproducibility
_rng = np.random.RandomState(0x5EED)
GEAR = _rng.randint(0, 2**64, size=256, dtype=np.uint64)


def gear_fingerprints(buf: np.ndarray) -> np.ndarray:
    """Exact gear fingerprint at every position (64-tap windowed form)."""
    g = GEAR[buf]
    fp = np.zeros(len(buf), np.uint64)
    for j in range(64):
        if j >= len(buf):
            break
        shifted = g[: len(buf) - j] << np.uint64(j)
        fp[j:] += shifted
    return fp


@dataclass(frozen=True)
class FastCDC:
    min_size: int = 16 * 1024
    avg_size: int = 64 * 1024
    max_size: int = 256 * 1024

    @property
    def mask_s(self) -> np.uint64:
        bits = int(np.log2(self.avg_size)) + 2
        return np.uint64((1 << bits) - 1)

    @property
    def mask_l(self) -> np.uint64:
        bits = int(np.log2(self.avg_size)) - 2
        return np.uint64((1 << bits) - 1)

    def chunks(self, data) -> Iterator[Tuple[int, int]]:
        buf = np.frombuffer(data, np.uint8)
        n = len(buf)
        if n == 0:
            return
        fp = gear_fingerprints(buf)
        cand_s = np.nonzero((fp & self.mask_s) == 0)[0]
        cand_l = np.nonzero((fp & self.mask_l) == 0)[0]
        start = 0
        while start < n:
            lo = start + self.min_size
            normal = start + self.avg_size
            hi = start + self.max_size
            cut = min(hi, n)
            # strict mask in [lo, normal)
            i = np.searchsorted(cand_s, lo)
            if i < len(cand_s) and cand_s[i] < min(normal, n):
                cut = int(cand_s[i]) + 1
            else:
                j = np.searchsorted(cand_l, normal)
                if j < len(cand_l) and cand_l[j] < min(hi, n):
                    cut = int(cand_l[j]) + 1
            yield start, min(cut, n)
            start = cut


class ChunkDedup:
    """CDC-based dedup over raw file bytes."""

    def __init__(self, cdc: Optional[FastCDC] = None):
        self.cdc = cdc or FastCDC()
        self.index: Dict[str, int] = {}
        self.stats = DedupStats()

    def scan_bytes(self, data, location: str = "") -> List[Tuple[int, int, str, bool]]:
        mv = memoryview(data)
        out = []
        for b, e in self.cdc.chunks(mv):
            digest = hashlib.sha256(mv[b:e]).hexdigest()
            is_new = digest not in self.index
            if is_new:
                self.index[digest] = e - b
            self.stats.observe(e - b, is_new)
            out.append((b, e, digest, is_new))
        return out

    def scan_file(self, path: str, location: Optional[str] = None):
        with open(path, "rb") as f:
            return self.scan_bytes(f.read(), location or path)
