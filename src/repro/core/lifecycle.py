"""Container lifecycle: immutable versions, refcounted GC, fsck reporting.

ZipLLM's storage win comes from cross-model sharing — tensor-dedup records
and BitX delta frames inside one repo's container point into containers
owned by *other* repos — so container lifetime is a correctness problem,
not a cleanup nicety. This module makes containers immutable *versions*:

* Every container write is a generation ``key@gN`` (gen 0 keeps the legacy
  ``<key>.bitx`` path, so PR-1 stores load unchanged; later generations live
  at ``<key>@gN.bitx``). Re-registering a key writes a new generation
  copy-on-write; dependants keep resolving against the generation they were
  pinned to at ingest time.
* ``ContainerLifecycle`` tracks the version graph: vertices are container
  versions, edges are "version A's records resolve into version B" (one
  edge per dependant/target pair, recorded at ingest). Anchors — the
  versions the store's live ``file_index`` entries point at — are supplied
  by the store at GC time.
* ``collect(anchors)`` is the refcounted sweep: a version survives iff it
  is reachable from an anchor through the edge graph (reachability ==
  cascading refcount decrement: reclaiming a version releases its outgoing
  references, which may free its targets in the same pass).
* ``quarantine`` parks a corrupted version out of the retrieval path while
  keeping its graph node (and therefore its dependencies) alive, so a
  repair can re-pin or restore without collateral GC; ``unquarantine`` is
  the inverse, applied after a healthy replica's bytes are swapped back in.
* ``tombstones`` are deletion markers (``key -> (gen, ts)``): a delete
  records the highest generation it covered, so replica anti-entropy can
  distinguish "this key was deleted" from "this replica never saw this
  key" — and, because generations are monotonic per key, a later re-upload
  (``gen > tombstone gen``) legitimately clears the marker instead of
  being mistaken for a resurrection.

The store (``repro.core.pipeline.ZLLMStore``) owns the policy: which
versions are anchored, how ``tensor_locations`` entries are scrubbed after
a sweep, and what ``fsck`` checks. This module owns the mechanism.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

__all__ = ["ContainerLifecycle", "VersionInfo", "FsckReport", "make_vid"]


def make_vid(key: str, gen: int) -> str:
    """Canonical version id for container ``key`` at generation ``gen``."""
    return f"{key}@g{gen}"


@dataclass
class VersionInfo:
    """One immutable container version on disk."""

    key: str
    gen: int
    path: str
    nbytes: int
    quarantined: bool = False

    @property
    def vid(self) -> str:
        return make_vid(self.key, self.gen)


@dataclass
class FsckReport:
    """Outcome of a store fsck walk.

    ``dangling`` — references (tensor hash or file ref) that no longer
    resolve to a live container frame. ``corrupt`` — containers that fail
    structural or sha256 spot checks. ``orphans`` — container files on disk
    that no live or quarantined version references (crash debris from an
    interrupted ingest; ``repair=True`` deletes them).
    ``repaired``/``quarantined`` record what a ``repair=True`` pass actually
    did; a repaired reference is not also listed as dangling.
    """

    checked_versions: int = 0
    checked_files: int = 0
    checked_refs: int = 0
    spot_checked: int = 0
    dangling: List[Tuple[str, str]] = field(default_factory=list)
    corrupt: List[Tuple[str, str]] = field(default_factory=list)
    orphans: List[str] = field(default_factory=list)
    repaired: List[Tuple[str, str]] = field(default_factory=list)
    quarantined: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.dangling and not self.corrupt

    def summary(self) -> Dict:
        return {
            "ok": self.ok,
            "checked_versions": self.checked_versions,
            "checked_files": self.checked_files,
            "checked_refs": self.checked_refs,
            "spot_checked": self.spot_checked,
            "n_dangling": len(self.dangling),
            "n_corrupt": len(self.corrupt),
            "n_orphans": len(self.orphans),
            "n_repaired": len(self.repaired),
            "n_quarantined": len(self.quarantined),
        }


class ContainerLifecycle:
    """Version graph + refcounted GC for a store's containers."""

    def __init__(self):
        self.versions: Dict[str, VersionInfo] = {}      # vid -> live version
        self.max_gen: Dict[str, int] = {}               # key -> highest gen ever
        self.edges: Dict[str, Set[str]] = {}            # dependant vid -> target vids
        self.tombstones: Dict[str, Tuple[int, float]] = {}  # key -> (gen, ts)
        self.reclaimed_bytes = 0
        self.n_collected = 0
        self.n_gc_runs = 0
        self._live_bytes = 0  # running sum: O(1) live_bytes() on the ingest path

    # -- registration ----------------------------------------------------
    def next_generation(self, key: str) -> int:
        """Generation the next container write for ``key`` should use.
        Monotonic per key — generations of reclaimed versions are never
        reused, so stale paths can't be resurrected."""
        return self.max_gen[key] + 1 if key in self.max_gen else 0

    def register_version(self, key: str, gen: int, path: str, nbytes: int) -> VersionInfo:
        info = VersionInfo(key, gen, path, nbytes)
        self.versions[info.vid] = info
        self.max_gen[key] = max(gen, self.max_gen.get(key, -1))
        self._live_bytes += nbytes
        return info

    def add_edge(self, src_vid: str, dst_vid: str) -> None:
        """Record that container ``src_vid`` resolves into ``dst_vid``
        (a dedup record or a BitX base reference). Self-edges are dropped —
        a container trivially keeps itself alive while anchored."""
        if src_vid != dst_vid:
            self.edges.setdefault(src_vid, set()).add(dst_vid)

    def set_nbytes(self, key: str, gen: int, nbytes: int) -> None:
        """Fix up a version's on-disk size after a deferred container write
        (the pipelined ingest engine registers the version at decision time,
        before the bytes hit disk)."""
        v = self.versions.get(make_vid(key, gen))
        if v is None:
            return
        if not v.quarantined:
            self._live_bytes += nbytes - v.nbytes
        v.nbytes = nbytes

    def discard(self, key: str, gen: int) -> None:
        """Drop a version whose container write failed — the inverse of
        ``register_version`` for a version that never made it to disk.
        ``max_gen`` is left alone so the generation number is never reused."""
        v = self.versions.pop(make_vid(key, gen), None)
        if v is None:
            return
        if not v.quarantined:
            self._live_bytes -= v.nbytes
        self.edges.pop(v.vid, None)

    # -- queries ---------------------------------------------------------
    def get(self, key: str, gen: int) -> Optional[VersionInfo]:
        return self.versions.get(make_vid(key, gen))

    def exists(self, key: str, gen: int) -> bool:
        v = self.versions.get(make_vid(key, gen))
        return v is not None and not v.quarantined

    def version_path(self, key: str, gen: int) -> str:
        v = self.versions.get(make_vid(key, gen))
        if v is None:
            raise KeyError(f"container version {make_vid(key, gen)} is unknown "
                           f"or was garbage-collected")
        if v.quarantined:
            raise RuntimeError(f"container version {v.vid} is quarantined "
                               f"(fsck found it corrupt): {v.path}")
        return v.path

    def live_bytes(self) -> int:
        return self._live_bytes

    def refcounts(self) -> Dict[str, int]:
        """Incoming-edge count per live version (anchors not included)."""
        counts = {vid: 0 for vid in self.versions}
        for src, dsts in self.edges.items():
            if src in self.versions:            # edges of reclaimed versions are gone
                for dst in dsts:
                    if dst in counts:
                        counts[dst] += 1
        return counts

    # -- GC ----------------------------------------------------------------
    def reachable(self, roots: Iterable[str]) -> Set[str]:
        """Live version ids transitively reachable from ``roots`` over the
        edge graph — the mark phase shared by stop-the-world ``collect``,
        the store's incremental GC steps, and ``compact()``."""
        live: Set[str] = set()
        stack = [r for r in roots if r in self.versions]
        while stack:
            vid = stack.pop()
            if vid in live:
                continue
            live.add(vid)
            for dst in self.edges.get(vid, ()):
                if dst not in live and dst in self.versions:
                    stack.append(dst)
        return live

    def gc_roots(self, anchors: Iterable[str]) -> List[str]:
        """``anchors`` plus every quarantined version: quarantined versions
        are roots too — their dependency targets must stay alive so a later
        restore/repair still resolves (the documented quarantine
        guarantee)."""
        roots = [a for a in anchors]
        roots += [vid for vid, v in self.versions.items() if v.quarantined]
        return roots

    def retire(self, key: str, gen: int) -> Optional[VersionInfo]:
        """Reclaim one version (GC / compaction accounting: counts toward
        ``reclaimed_bytes``/``n_collected``, unlike :meth:`discard` which is
        for versions that never made it to disk). The caller is responsible
        for having proven the version dead and for deleting the file."""
        v = self.versions.pop(make_vid(key, gen), None)
        if v is None:
            return None
        self.edges.pop(v.vid, None)
        if not v.quarantined:
            self._live_bytes -= v.nbytes
        self.reclaimed_bytes += v.nbytes
        self.n_collected += 1
        return v

    def collect(self, anchors: Iterable[str]) -> List[VersionInfo]:
        """Reclaim every version unreachable from ``anchors``.

        Reachability over the edge graph is the cascading refcount
        decrement: a superseded generation survives exactly as long as some
        anchored dependant (transitively) points into it. Quarantined
        versions are pinned — they are kept even when unreachable, so a
        later repair can still inspect them.

        Returns the reclaimed versions; the caller deletes the files and
        scrubs its hash indexes.
        """
        self.n_gc_runs += 1
        live = self.reachable(self.gc_roots(anchors))
        reclaimed = [v for vid, v in self.versions.items()
                     if vid not in live and not v.quarantined]
        for v in reclaimed:
            self.retire(v.key, v.gen)
        return reclaimed

    def quarantine(self, key: str, gen: int, new_path: str) -> None:
        """Mark a version corrupt and point it at its quarantine location.
        The graph node stays (keeping its dependency targets alive) so a
        repair can re-pin dependants before the version is dropped."""
        v = self.versions[make_vid(key, gen)]
        if not v.quarantined:
            self._live_bytes -= v.nbytes
        v.quarantined = True
        v.path = new_path

    def unquarantine(self, key: str, gen: int, new_path: str) -> None:
        """Return a quarantined version to the live set after its bytes were
        restored (verbatim, sha256-verified) from a healthy replica. The
        inverse of :meth:`quarantine`: the version becomes retrievable again
        at ``new_path`` and counts toward live bytes."""
        v = self.versions[make_vid(key, gen)]
        if v.quarantined:
            self._live_bytes += v.nbytes
        v.quarantined = False
        v.path = new_path

    # -- tombstones --------------------------------------------------------
    def record_tombstone(self, key: str, gen: int, ts: float) -> None:
        """Record that ``key`` was deleted at a moment when its highest
        known generation was ``gen``. Merging keeps the max generation (and
        the freshest timestamp), so tombstones are idempotent and
        commutative across replicas."""
        old = self.tombstones.get(key)
        if old is None:
            self.tombstones[key] = (gen, ts)
        else:
            self.tombstones[key] = (max(gen, old[0]), max(ts, old[1]))

    def tombstone_for(self, key: str) -> Optional[Tuple[int, float]]:
        return self.tombstones.get(key)

    def tombstone_covers(self, key: str, gen: int) -> bool:
        """True when a recorded delete supersedes generation ``gen`` of
        ``key`` — a replica holding such a generation must drop it rather
        than re-ship it (anti-resurrection rule)."""
        t = self.tombstones.get(key)
        return t is not None and gen <= t[0]

    def clear_tombstone(self, key: str) -> None:
        """A re-upload produced a generation above the tombstone's: the
        delete marker has been superseded and must stop deleting."""
        self.tombstones.pop(key, None)

    def prune_tombstones(self, now: float, ttl_s: float) -> int:
        """Drop tombstones older than ``ttl_s`` (anti-entropy has long since
        converged every replica). Returns how many were pruned."""
        stale = [k for k, (_, ts) in self.tombstones.items() if now - ts > ttl_s]
        for k in stale:
            del self.tombstones[k]
        return len(stale)

    # -- persistence -------------------------------------------------------
    def to_json(self) -> Dict:
        return {
            "versions": [[v.key, v.gen, v.path, v.nbytes, v.quarantined]
                         for v in self.versions.values()],
            "max_gen": self.max_gen,
            "edges": {src: sorted(dsts) for src, dsts in self.edges.items() if dsts},
            # v4: deletion markers ride the lifecycle blob (absent pre-v4 —
            # from_json defaults them empty, so older indexes load unchanged)
            "tombstones": {k: [g, ts] for k, (g, ts) in self.tombstones.items()},
            "reclaimed_bytes": self.reclaimed_bytes,
            "n_collected": self.n_collected,
            "n_gc_runs": self.n_gc_runs,
        }

    @staticmethod
    def from_json(d: Dict) -> "ContainerLifecycle":
        lc = ContainerLifecycle()
        for key, gen, path, nbytes, quarantined in d.get("versions", []):
            info = lc.register_version(key, int(gen), path, int(nbytes))
            if quarantined:
                info.quarantined = True
                lc._live_bytes -= info.nbytes
        for key, gen in d.get("max_gen", {}).items():
            lc.max_gen[key] = max(int(gen), lc.max_gen.get(key, -1))
        lc.edges = {src: set(dsts) for src, dsts in d.get("edges", {}).items()}
        lc.tombstones = {k: (int(g), float(ts))
                         for k, (g, ts) in d.get("tombstones", {}).items()}
        lc.reclaimed_bytes = int(d.get("reclaimed_bytes", 0))
        lc.n_collected = int(d.get("n_collected", 0))
        lc.n_gc_runs = int(d.get("n_gc_runs", 0))
        return lc
