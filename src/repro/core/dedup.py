"""Deduplication at four granularities (paper §3.5, §4.1, §5.3.1).

* FileDedup   — sha256 over whole files; catches exact re-uploads (Table 2).
* TensorDedup — the paper's contribution: hash each tensor independently
  (boundaries come free from the safetensors header), ~the reduction ratio of
  CDC at 3 orders of magnitude less metadata, embarrassingly parallel, and —
  crucially — alignment-preserving, so unique tensors remain compressible by
  model-aware compressors (the zLLM synergy).
* LayerDedup  — coarser: hash per layer group (all tensors with the same
  layer index); one changed tensor breaks the whole layer (Table 5).
* ChunkDedup  — the CDC baseline lives in ``repro.core.chunkdedup``.

Each engine exposes ``scan_file`` returning (hits, misses) against its global
index plus byte-accurate accounting, so the benchmarks can replay Table 5.

Interplay with the device-batched encode path: dedup decisions run in the
pipeline's *serial* decision stage, strictly before any codec work, and are
pure functions of the tensor hashes — so they are identical no matter which
``ArrayBackend`` the store was built with, and a dedup'd tensor never reaches
the batched kernel launches at all (its record carries zero payload). The
hash counts these engines report are therefore backend-invariant.
"""

from __future__ import annotations

import hashlib
import os
import re
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.formats.safetensors import SafetensorsFile, TensorInfo

__all__ = ["sha256_bytes", "sha256_file", "FileDedup", "TensorDedup", "LayerDedup",
           "DedupStats"]

# FileDedup streams whole files through sha256 in fixed chunks so peak RSS
# stays flat on multi-GB shards (the hash state is 64 B regardless of input).
HASH_CHUNK_BYTES = 8 << 20


def sha256_bytes(data) -> str:
    return hashlib.sha256(data).hexdigest()


def sha256_file(path: str, chunk_bytes: int = HASH_CHUNK_BYTES) -> Tuple[str, int]:
    """Streaming whole-file sha256. Returns (hexdigest, bytes hashed)."""
    h = hashlib.sha256()
    size = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(chunk_bytes)
            if not chunk:
                break
            h.update(chunk)
            size += len(chunk)
    return h.hexdigest(), size


@dataclass
class DedupStats:
    """Byte accounting for one dedup engine over an ingested corpus."""

    total_bytes: int = 0
    unique_bytes: int = 0
    n_units: int = 0
    n_unique: int = 0
    unit_sizes: List[int] = field(default_factory=list)

    @property
    def saved_bytes(self) -> int:
        return self.total_bytes - self.unique_bytes

    @property
    def reduction_ratio(self) -> float:
        return self.saved_bytes / self.total_bytes if self.total_bytes else 0.0

    def metadata_bytes(self, per_entry: int = 64) -> int:
        """Index footprint (paper assumes 64 B/entry: hash, location, refcount)."""
        return self.n_unique * per_entry

    def observe(self, size: int, is_new: bool):
        self.total_bytes += size
        self.n_units += 1
        if is_new:
            self.unique_bytes += size
            self.n_unique += 1
            self.unit_sizes.append(size)


class FileDedup:
    def __init__(self):
        self.index: Dict[str, str] = {}     # hash -> first location
        self.stats = DedupStats()

    def scan_file(self, path: str, location: Optional[str] = None) -> Tuple[str, bool]:
        digest, size = sha256_file(path)
        return digest, self.observe(digest, size, location or path)

    def observe(self, digest: str, size: int, location: Optional[str] = None) -> bool:
        """Register a whole-file hash computed elsewhere (the pipelined ingest
        engine hashes upload N+1 on a worker thread while upload N encodes;
        only this registration runs on the serial decision stage). Returns
        True when the hash is new to the index."""
        is_new = digest not in self.index
        if is_new:
            self.index[digest] = location or digest
        self.stats.observe(size, is_new)
        return is_new

    def forget(self, digest: str) -> None:
        """Drop a hash whose last copy was deleted, so a future identical
        upload is stored fresh instead of dedup'd against a dead entry.
        Cumulative ingest stats are left untouched."""
        self.index.pop(digest, None)


class TensorDedup:
    """Per-tensor content hashing over the safetensors mmap (zero-copy).

    ``hash_calls`` counts every tensor hash computed through this engine
    (thread-safe — the parallel ingest pool hashes concurrently); the
    pipeline tests use it to assert a base model is hashed exactly once no
    matter how many fine-tunes are ingested against it.
    """

    def __init__(self):
        self.index: Dict[str, str] = {}     # tensor hash -> location "repo/file:tensor"
        self.stats = DedupStats()
        self.hash_calls = 0
        self._counter_lock = threading.Lock()

    def hash_tensor(self, raw: memoryview) -> str:
        with self._counter_lock:
            self.hash_calls += 1
        return sha256_bytes(raw)

    def forget(self, digest: str) -> None:
        """Drop a tensor hash whose backing container was garbage-collected
        (cumulative stats stay; the pipeline also scrubs tensor_locations)."""
        self.index.pop(digest, None)

    def scan_file(self, path: str, location: Optional[str] = None):
        """Returns [(TensorInfo, hash, is_new)] in serialization order."""
        out = []
        loc = location or path
        with SafetensorsFile(path) as sf:
            for ti in sf.infos:
                digest = self.hash_tensor(sf.tensor_bytes(ti.name))
                is_new = digest not in self.index
                if is_new:
                    self.index[digest] = f"{loc}:{ti.name}"
                self.stats.observe(ti.nbytes, is_new)
                out.append((ti, digest, is_new))
        return out


_LAYER_RE = re.compile(r"(?:^|\.)(?:layers?|blocks?|h)[._](\d+)[._]")


def layer_key(tensor_name: str) -> str:
    """Group tensors into layers by the layer index in their name; tensors
    without one (embeddings, final norm) each form their own group."""
    m = _LAYER_RE.search(tensor_name)
    if m:
        return f"layer.{m.group(1)}"
    return f"top.{tensor_name}"


class LayerDedup:
    def __init__(self):
        self.index: Dict[str, str] = {}
        self.stats = DedupStats()

    def scan_file(self, path: str, location: Optional[str] = None):
        loc = location or path
        groups: Dict[str, List[TensorInfo]] = {}
        out = []
        with SafetensorsFile(path) as sf:
            for ti in sf.infos:
                groups.setdefault(layer_key(ti.name), []).append(ti)
            for key, infos in groups.items():
                h = hashlib.sha256()
                size = 0
                for ti in infos:
                    h.update(sf.tensor_bytes(ti.name))
                    size += ti.nbytes
                digest = h.hexdigest()
                is_new = digest not in self.index
                if is_new:
                    self.index[digest] = f"{loc}:{key}"
                self.stats.observe(size, is_new)
                out.append((key, digest, is_new, size))
        return out
