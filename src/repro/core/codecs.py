"""Codec registry: the store's pluggable per-tensor encode/decode lanes.

Every payload lane a container can stamp (``bitx`` / ``bitxq`` / ``zipnn`` /
``raw`` / ``stored`` / ``dedup``) is registered here as a pair of PURE
functions of (bytes, backend): given the same tensor bytes, the same entropy
settings and the same :class:`~repro.core.bitx.ArrayBackend`, a codec must
emit identical frames on every engine (serial, threaded, process-entropy,
device-batched) — that purity is what lets the pipeline's ordered merge
produce bit-identical containers no matter how the work is scheduled.

Registry contract:

* ``register_codec(name, encode, decode)`` — ``encode(runtime, EncodeInput)
  -> (final_codec, frames, raw_size)`` may *downgrade* the lane (``raw`` →
  ``stored`` when entropy coding would grow the bytes; ``bitxq`` → the
  standalone ``raw``/``stored`` outcome when the delta does not beat it).
  An encode may instead return a 4-tuple ``(final_codec, frames, raw_size,
  extras)`` where ``extras`` is a dict of :class:`TensorRecord` stamp
  fields the decode side must see (the quantized-delta lane stamps
  ``base_dtype``/``qscale_bits``/``qzero_point`` this way). ``decode(runtime,
  record, frames, np_dtype, base_resolver, pool_resolver) -> np.ndarray``
  must invert it bit-exactly.
* ``get_codec(name)`` — raises ``ValueError`` naming the unknown codec (a
  container stamped by a newer build fails loudly, never silently).
* Codecs never touch zstd contexts directly: the :class:`CodecRuntime`
  handle owns them per-thread (compressor contexts are NOT thread-safe) and
  asserts ownership on every use, so an implementation cannot accidentally
  smuggle a context across threads.

Array math (XOR delta, byte-plane split/merge) goes through
``runtime.backend`` — the :class:`~repro.core.bitx.ArrayBackend` selected at
store construction — so the numpy host path and the batched jax/Pallas
device path share one dispatch point.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import zstd_compat as zstd

__all__ = [
    "Codec",
    "CodecRuntime",
    "EncodeInput",
    "get_codec",
    "raw_or_stored",
    "register_codec",
    "registered_codecs",
]


class _ThreadGuardedCtx:
    """A zstd context bound to the thread that materialized it.

    zstd compressor/decompressor contexts are not thread-safe; sharing one
    mid-operation corrupts frames silently. The guard makes the failure mode
    loud: every use asserts the calling thread is the owning thread.
    """

    __slots__ = ("_ctx", "_owner")

    def __init__(self, ctx):
        self._ctx = ctx
        self._owner = threading.get_ident()

    def _check(self) -> None:
        assert self._owner == threading.get_ident(), (
            f"zstd context created on thread {self._owner} used from thread "
            f"{threading.get_ident()} — contexts are not thread-safe; go "
            f"through CodecRuntime.compress/decompress, which are per-thread")

    def compress(self, data) -> bytes:
        self._check()
        return self._ctx.compress(data)

    def decompress(self, data) -> bytes:
        self._check()
        return self._ctx.decompress(data)


class CodecRuntime:
    """Execution handle passed to every registered codec.

    Owns (a) the :class:`~repro.core.bitx.ArrayBackend` for array math and
    (b) the zstd entropy contexts, kept in thread-local storage and wrapped
    in an owner-thread assertion — one runtime is shared across a worker
    pool and each worker lazily gets its own context pair. Frames are a pure
    function of (bytes, level, threads), so per-thread contexts never change
    the emitted bytes.
    """

    def __init__(self, level: int = 3, threads: int = 0, backend=None):
        if backend is None:
            from repro.core.bitx import get_backend
            backend = get_backend("numpy")
        self.level = level
        self.threads = threads
        self.backend = backend
        self._tls = threading.local()

    def _compressor(self) -> _ThreadGuardedCtx:
        ctx = getattr(self._tls, "cctx", None)
        if ctx is None:
            ctx = self._tls.cctx = _ThreadGuardedCtx(
                zstd.ZstdCompressor(level=self.level, threads=self.threads))
        return ctx

    def _decompressor(self) -> _ThreadGuardedCtx:
        ctx = getattr(self._tls, "dctx", None)
        if ctx is None:
            ctx = self._tls.dctx = _ThreadGuardedCtx(zstd.ZstdDecompressor())
        return ctx

    def compress(self, data) -> bytes:
        return self._compressor().compress(data)

    def decompress(self, data) -> bytes:
        return self._decompressor().decompress(data)


@dataclass
class EncodeInput:
    """What a codec's encode lane consumes.

    ``data`` is the tensor payload: an ndarray for the plane codecs, raw
    bytes for ``raw``/``stored``. ``base`` is the aligned base tensor for
    ``bitx``/``bitxq``. ``base_dtype`` names the base's safetensors tag for
    the dtype-crossing ``bitxq`` lane (the base arrives as a bit view —
    uint16 for BF16 — so its dtype is not recoverable from the array alone).
    ``planes`` short-circuits the array stage: the device-batched encode
    path splits planes for a whole bucket in one kernel launch and hands
    them in pre-computed, leaving the codec only the entropy stage — the
    frames are identical either way because the plane bytes are.
    ``raw_size`` carries the pool payload size for zero-frame ``dedup``
    records.
    """

    data: Any = None
    base: Optional[np.ndarray] = None
    planes: Optional[Sequence[np.ndarray]] = None
    raw_size: int = 0
    base_dtype: Optional[str] = None


@dataclass(frozen=True)
class Codec:
    name: str
    encode: Callable[[CodecRuntime, EncodeInput], Tuple[str, List[bytes], int]]
    decode: Callable[..., np.ndarray]


_REGISTRY: Dict[str, Codec] = {}


def register_codec(name: str, encode: Callable, decode: Callable,
                   *, replace: bool = False) -> Codec:
    """Register a codec lane. ``encode``/``decode`` must be pure functions of
    (bytes, backend) — see the module docstring for the exact signatures."""
    if name in _REGISTRY and not replace:
        raise ValueError(f"codec {name!r} already registered "
                         f"(pass replace=True to override)")
    codec = Codec(name, encode, decode)
    _REGISTRY[name] = codec
    return codec


def get_codec(name: str) -> Codec:
    """Look a codec up by its stamped name; unknown names fail loudly so a
    container written by a newer build is never mis-decoded."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r} (registered: "
            f"{', '.join(sorted(_REGISTRY))})") from None


def registered_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def raw_or_stored(data: bytes, frame: bytes) -> Tuple[str, bytes]:
    """Entropy-stage decision for raw-kind tensors: keep the compressed frame
    only when it actually shrank the input; otherwise store the bytes
    VERBATIM under codec ``stored`` (the serving layer's zero-copy
    ``os.sendfile`` span). Pure function of (bytes, entropy backend), so
    every engine emits identical containers."""
    if len(frame) < len(data):
        return "raw", frame
    return "stored", data


# ---------------------------------------------------------------------------
# The six built-in lanes (paper §4.3/§4.4): BitX XOR-delta planes, the
# quantized dtype-crossing delta (bitxq), ZipNN byte planes, raw zstd with
# the stored downgrade, verbatim stored bytes, and zero-payload dedup
# references.
# ---------------------------------------------------------------------------

def _entropy_planes(rt: CodecRuntime, planes: Sequence) -> List[bytes]:
    return [rt.compress(p.tobytes() if isinstance(p, np.ndarray) else bytes(p))
            for p in planes]


def _plane_arrays(rt: CodecRuntime, frames: Sequence) -> List[np.ndarray]:
    return [np.frombuffer(rt.decompress(bytes(f)), np.uint8) for f in frames]


def _encode_bitx(rt: CodecRuntime, inp: EncodeInput):
    if inp.data is not None:
        ft = np.asarray(inp.data)
        raw = int(ft.nbytes)
        planes = (inp.planes if inp.planes is not None else
                  rt.backend.xor_delta_planes(np.asarray(inp.base).reshape(-1),
                                              ft.reshape(-1)))
    else:  # device-batched path: planes pre-split, only entropy remains
        planes, raw = inp.planes, int(inp.raw_size)
    return "bitx", _entropy_planes(rt, planes), raw


def _decode_bitx(rt, r, frames, np_dtype, base_resolver, pool_resolver):
    base = base_resolver(r.base_hash)
    if isinstance(base, (bytes, memoryview)):
        base = np.frombuffer(base, np_dtype)
    planes = _plane_arrays(rt, frames)
    return rt.backend.merge_planes_xor(planes, base.reshape(-1)).reshape(r.shape)


# -- quantized (dtype-crossing) delta lane ----------------------------------
# An int8 repack of a float family base deltas against the ORIGINAL base via
# dequantize-predict-residual: the base is expanded to float32, a symmetric
# per-tensor scale is derived from the base itself, the base is re-quantized
# onto the int8 grid as a *prediction*, and only the XOR residual between
# prediction and actual quantized bytes is entropy-coded. Everything the
# decode side needs to replay the prediction (base hash, base dtype, the
# scale's exact f32 bit pattern, the zero point) is stamped on the record,
# so the lane is lossless by construction — ZipNN (arXiv:2411.05239) and
# Huff-LLM (arXiv:2502.00922) both motivate keeping dtype-aware lanes
# bit-exact. The prediction is ALWAYS computed host-side in numpy (float32
# arithmetic is not guaranteed bit-stable across accelerators); only the
# elementwise XOR/merge goes through the ArrayBackend, so numpy and jax
# engines emit and decode identical containers.

_QDELTA_INT_RANGE = 127  # symmetric int8 grid: [-127, 127]


def _base_to_f32(base: Any, base_dtype: str) -> np.ndarray:
    """Expand a base tensor (bytes or bit-view ndarray) to float32, exactly.

    BF16 arrives as a uint16 bit view; shifting into the high half of a
    uint32 reconstructs the float32 it truncates — exact by definition, no
    ml_dtypes dependency. F16/F32 widen losslessly via astype.
    """
    from repro.formats.safetensors import STR_TO_DTYPE
    np_dtype = STR_TO_DTYPE[base_dtype]
    if isinstance(base, (bytes, memoryview)):
        base = np.frombuffer(base, np_dtype)
    else:
        base = np.asarray(base).reshape(-1).view(np_dtype)
    if base_dtype == "BF16":
        bits = base.view("<u2").astype(np.uint32) << np.uint32(16)
        return bits.view(np.float32)
    return base.astype(np.float32)


def _qdelta_scale_bits(base_f32: np.ndarray) -> int:
    """Symmetric per-tensor scale derived from the BASE: max finite |x| / 127,
    returned as the float32 bit pattern (the container stamps bits, not a
    decimal, so encode and decode replay the identical scale). Degenerate
    bases (all-zero / no finite values) fall back to scale 1.0."""
    finite = base_f32[np.isfinite(base_f32)]
    amax = float(np.abs(finite).max()) if finite.size else 0.0
    scale = np.float32(amax / _QDELTA_INT_RANGE) if amax > 0.0 else np.float32(1.0)
    if not np.isfinite(scale) or scale == 0.0:
        scale = np.float32(1.0)
    return int(scale.view(np.uint32))


def _qdelta_predict(base_f32: np.ndarray, scale_bits: int,
                    zero_point: int) -> np.ndarray:
    """Re-quantize the base onto the int8 grid — the decode side's prediction.
    Pure float32 numpy math: divide, round-to-nearest-even, shift by the zero
    point, clip to the symmetric range. Non-finite base elements predict the
    zero point (their residual then carries the actual bits verbatim)."""
    scale = np.array(scale_bits, dtype=np.uint32).view(np.float32)[()]
    bf = np.where(np.isfinite(base_f32), base_f32, np.float32(0.0))
    q = np.rint(bf / scale) + np.float32(zero_point)
    return np.clip(q, -_QDELTA_INT_RANGE, _QDELTA_INT_RANGE).astype(np.int8)


def _encode_bitxq(rt: CodecRuntime, inp: EncodeInput):
    q = np.asarray(inp.data).reshape(-1).view(np.int8)
    raw = int(q.nbytes)
    base_f32 = _base_to_f32(inp.base, inp.base_dtype)
    scale_bits = _qdelta_scale_bits(base_f32)
    zero_point = 0
    pred = _qdelta_predict(base_f32, scale_bits, zero_point)
    planes = rt.backend.xor_delta_planes(pred, q)
    frames = _entropy_planes(rt, planes)
    # lane-vs-standalone decision, a pure function of the tensor bytes: the
    # delta only ships when it beats what the standalone raw lane would
    # store for the same bytes; otherwise downgrade to that exact outcome
    # (the merge stage nulls the base reference on a 3-tuple downgrade).
    data = q.tobytes()
    final, payload = raw_or_stored(data, rt.compress(data))
    if sum(len(f) for f in frames) < len(payload):
        return "bitxq", frames, raw, {"base_dtype": inp.base_dtype,
                                      "qscale_bits": scale_bits,
                                      "qzero_point": zero_point}
    return final, [payload], raw


def _decode_bitxq(rt, r, frames, np_dtype, base_resolver, pool_resolver):
    base_f32 = _base_to_f32(base_resolver(r.base_hash), r.base_dtype)
    pred = _qdelta_predict(base_f32, r.qscale_bits, r.qzero_point or 0)
    planes = _plane_arrays(rt, frames)
    q = rt.backend.merge_planes_xor(planes, pred)
    return q.view(np_dtype).reshape(r.shape)


def _encode_zipnn(rt: CodecRuntime, inp: EncodeInput):
    if inp.data is not None:
        x = np.asarray(inp.data)
        raw = int(x.nbytes)
        planes = (inp.planes if inp.planes is not None else
                  rt.backend.byte_planes(x))
    else:  # device-batched path: planes pre-split, only entropy remains
        planes, raw = inp.planes, int(inp.raw_size)
    return "zipnn", _entropy_planes(rt, planes), raw


def _decode_zipnn(rt, r, frames, np_dtype, base_resolver, pool_resolver):
    planes = _plane_arrays(rt, frames)
    return rt.backend.merge_planes(planes, np_dtype, r.shape)


def _encode_raw(rt: CodecRuntime, inp: EncodeInput):
    data = bytes(inp.data)
    final, payload = raw_or_stored(data, rt.compress(data))
    return final, [payload], len(data)


def _decode_raw(rt, r, frames, np_dtype, base_resolver, pool_resolver):
    return np.frombuffer(rt.decompress(bytes(frames[0])), np_dtype).reshape(r.shape)


def _encode_stored(rt: CodecRuntime, inp: EncodeInput):
    data = bytes(inp.data)
    return "stored", [data], len(data)


def _decode_stored(rt, r, frames, np_dtype, base_resolver, pool_resolver):
    # verbatim frame: the on-disk bytes ARE the tensor bytes
    return np.frombuffer(frames[0], np_dtype).reshape(r.shape)


def _encode_dedup(rt: CodecRuntime, inp: EncodeInput):
    return "dedup", [], int(inp.raw_size)


def _decode_dedup(rt, r, frames, np_dtype, base_resolver, pool_resolver):
    arr = pool_resolver(r.self_hash)
    if isinstance(arr, (bytes, memoryview)):
        return np.frombuffer(arr, np_dtype).reshape(r.shape)
    return arr.reshape(r.shape)


register_codec("bitx", _encode_bitx, _decode_bitx)
register_codec("bitxq", _encode_bitxq, _decode_bitxq)
register_codec("zipnn", _encode_zipnn, _decode_zipnn)
register_codec("raw", _encode_raw, _decode_raw)
register_codec("stored", _encode_stored, _decode_stored)
register_codec("dedup", _encode_dedup, _decode_dedup)
